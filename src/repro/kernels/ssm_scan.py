"""Fused selective-scan Pallas kernel — the TPU answer to the falcon-mamba
memory wall found in §Perf.

The XLA associative-scan path materializes (B, S, d_inner, state) f32
decay/update/state tensors (log2(S) levels of them): ~50 TB accessed per
train step per device for falcon-mamba train_4k.  The CUDA mamba kernel
avoids this by keeping the recurrence state in SRAM; this kernel is the
VMEM version:

* grid = (batch, d_inner tiles, seq chunks), sequential over seq (TPU
  grid order guarantees the scratch carries across the seq dimension);
* the (d_tile, state) hidden state lives in a VMEM scratch buffer and is
  NEVER written to HBM (except nothing — y is the only output);
* HBM traffic = read dt/x (B,S,D), B/C (B,S,st), write y (B,S,D):
  ~3*B*S*D + 2*B*S*st elements total vs >= 2*log2(S)*B*S*D*st for the
  associative scan — a ~100x reduction at D=8192, st=16, S=4096.

Forward only (inference/prefill path; a custom-vjp training version would
recompute per-chunk states — noted in EXPERIMENTS §Perf).  Validated in
interpret mode against ref.ssm_scan_ref.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def ssm_scan_ref(dt, x, bmat, cmat, a):
    """Oracle: direct linear recurrence in fp32.

    dt, x: (B, S, D); bmat, cmat: (B, S, st); a: (D, st).
    Returns y (B, S, D), h_final (B, D, st).
    """
    bsz, s, d = x.shape
    st = bmat.shape[-1]
    decay = jnp.exp(dt[..., None].astype(jnp.float32) * a[None, None])
    upd = (dt * x)[..., None].astype(jnp.float32) * bmat[:, :, None, :].astype(jnp.float32)

    def step(h, inputs):
        dec, up, c = inputs
        h = dec * h + up
        y = jnp.sum(h * c[:, None, :], axis=-1)
        return h, y

    h0 = jnp.zeros((bsz, d, st), jnp.float32)
    h_final, ys = jax.lax.scan(
        step, h0,
        (decay.transpose(1, 0, 2, 3), upd.transpose(1, 0, 2, 3),
         cmat.astype(jnp.float32).transpose(1, 0, 2)),
    )
    return ys.transpose(1, 0, 2).astype(x.dtype), h_final


def _kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, y_ref, hlast_ref, h_scr, *, chunk: int):
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s_idx == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...].astype(jnp.float32)  # (d_tile, st)

    def body(i, h):
        dt_i = dt_ref[0, i, :].astype(jnp.float32)  # (d_tile,)
        x_i = x_ref[0, i, :].astype(jnp.float32)
        b_i = b_ref[0, i, :].astype(jnp.float32)  # (st,)
        c_i = c_ref[0, i, :].astype(jnp.float32)
        decay = jnp.exp(dt_i[:, None] * a)  # (d_tile, st)
        upd = (dt_i * x_i)[:, None] * b_i[None, :]
        h = decay * h + upd
        y_ref[0, i, :] = jnp.sum(h * c_i[None, :], axis=1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, body, h_scr[...])
    h_scr[...] = h

    @pl.when(s_idx == n_s - 1)
    def _final():
        hlast_ref[0] = h


def ssm_scan_pallas(
    dt: jax.Array,  # (B, S, D)
    x: jax.Array,
    bmat: jax.Array,  # (B, S, st)
    cmat: jax.Array,
    a: jax.Array,  # (D, st)
    *,
    chunk: int = 256,
    d_tile: int = 512,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Fused scan; returns (y (B,S,D), h_final (B,D,st))."""
    bsz, s, d = x.shape
    st = bmat.shape[-1]
    chunk = min(chunk, s)
    d_tile = min(d_tile, d)
    assert s % chunk == 0, (s, chunk)
    assert d % d_tile == 0, (d, d_tile)
    grid = (bsz, d // d_tile, s // chunk)

    y, h_final = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d_tile), lambda b, dd, ss: (b, ss, dd)),  # dt
            pl.BlockSpec((1, chunk, d_tile), lambda b, dd, ss: (b, ss, dd)),  # x
            pl.BlockSpec((1, chunk, st), lambda b, dd, ss: (b, ss, 0)),  # B
            pl.BlockSpec((1, chunk, st), lambda b, dd, ss: (b, ss, 0)),  # C
            pl.BlockSpec((d_tile, st), lambda b, dd, ss: (dd, 0)),  # A
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d_tile), lambda b, dd, ss: (b, ss, dd)),  # y
            pl.BlockSpec((1, d_tile, st), lambda b, dd, ss: (b, dd, 0)),  # h_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, d), x.dtype),
            jax.ShapeDtypeStruct((bsz, d, st), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d_tile, st), jnp.float32)],
        interpret=interpret,
    )(dt, x, bmat, cmat, a)
    return y, h_final


def fused_hbm_bytes(bsz: int, s: int, d: int, st: int, elem: int = 2) -> int:
    """Analytic HBM traffic of the fused kernel (for §Perf napkin math)."""
    return elem * (3 * bsz * s * d + 2 * bsz * s * st) + 4 * bsz * d * st


def xla_scan_hbm_bytes(bsz: int, s: int, d: int, st: int, elem: int = 4) -> int:
    """Lower bound for the associative-scan path: 2 tensors (decay, upd) of
    (B,S,D,st) read+written per scan level."""
    import math

    levels = max(1, int(math.log2(max(2, s))))
    return elem * 2 * 2 * bsz * s * d * st * levels
