"""Fused selective-scan Pallas kernel — the TPU answer to the falcon-mamba
memory wall found in §Perf.

The XLA associative-scan path materializes (B, S, d_inner, state) f32
decay/update/state tensors (log2(S) levels of them): ~50 TB accessed per
train step per device for falcon-mamba train_4k.  The CUDA mamba kernel
avoids this by keeping the recurrence state in SRAM; this kernel is the
VMEM version:

* grid = (batch, d_inner tiles, seq chunks), sequential over seq (TPU
  grid order guarantees the scratch carries across the seq dimension);
* the (d_tile, state) hidden state lives in a VMEM scratch buffer and is
  NEVER written to HBM (forward only checkpoints it once per seq chunk);
* HBM traffic = read dt/x (B,S,D), B/C (B,S,st), write y (B,S,D):
  ~3*B*S*D + 2*B*S*st elements total vs >= 2*log2(S)*B*S*D*st for the
  associative scan — a ~100x reduction at D=8192, st=16, S=4096.

**Differentiable** (``jax.custom_vjp``): the forward kernel additionally
writes the carried state at every seq-chunk *start* (the checkpoint
tensor ``(B, S/chunk, D, st)`` — a factor ``chunk`` smaller than the
activations the XLA path would save), and the backward is a second Pallas
kernel on the same ``(batch, d_tile, seq-chunk)`` grid running the seq
chunks in **reversed** order: each chunk recomputes its per-step states
from the checkpoint (one extra forward pass — the same VMEM-residency
argument as the forward), then runs the reverse linear-recurrence
accumulation ``g_{t-1} = g_t * decay_t`` entirely in VMEM, emitting
``d_dt / d_x / d_B / d_C / d_A`` in one pass.  Validated in interpret
mode against ``jax.grad`` of :func:`ssm_scan_ref` (``tests/
test_ssm_kernel.py``).

Cost model of the backward: HBM reads = the forward's inputs + dy +
checkpoints, writes = the five gradients; compute = 2x the forward
(recompute + reverse pass).  VMEM high-water = ``(chunk+1) * d_tile *
st`` f32 for the recomputed states — pick ``(chunk, d_tile)`` so that
fits (see docs/architecture.md §Training path).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis.registry import kernel_contract

from .merge_path import _interp


def ssm_scan_ref(dt, x, bmat, cmat, a):
    """Oracle: direct linear recurrence in fp32.

    dt, x: (B, S, D); bmat, cmat: (B, S, st); a: (D, st).
    Returns y (B, S, D), h_final (B, D, st).
    """
    bsz, s, d = x.shape
    st = bmat.shape[-1]
    decay = jnp.exp(dt[..., None].astype(jnp.float32) * a[None, None])
    upd = (dt * x)[..., None].astype(jnp.float32) * bmat[:, :, None, :].astype(jnp.float32)

    def step(h, inputs):
        dec, up, c = inputs
        h = dec * h + up
        y = jnp.sum(h * c[:, None, :], axis=-1)
        return h, y

    h0 = jnp.zeros((bsz, d, st), jnp.float32)
    h_final, ys = jax.lax.scan(
        step, h0,
        (decay.transpose(1, 0, 2, 3), upd.transpose(1, 0, 2, 3),
         cmat.astype(jnp.float32).transpose(1, 0, 2)),
    )
    return ys.transpose(1, 0, 2).astype(x.dtype), h_final


def _kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, y_ref, hlast_ref, *rest,
            chunk: int, checkpoints: bool):
    if checkpoints:
        hstart_ref, h_scr = rest
    else:
        (h_scr,) = rest
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s_idx == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    if checkpoints:
        # state at the START of this chunk — what the backward recomputes from
        hstart_ref[0, 0] = h_scr[...]

    a = a_ref[...].astype(jnp.float32)  # (d_tile, st)

    def body(i, h):
        dt_i = dt_ref[0, i, :].astype(jnp.float32)  # (d_tile,)
        x_i = x_ref[0, i, :].astype(jnp.float32)
        b_i = b_ref[0, i, :].astype(jnp.float32)  # (st,)
        c_i = c_ref[0, i, :].astype(jnp.float32)
        decay = jnp.exp(dt_i[:, None] * a)  # (d_tile, st)
        upd = (dt_i * x_i)[:, None] * b_i[None, :]
        h = decay * h + upd
        y_ref[0, i, :] = jnp.sum(h * c_i[None, :], axis=1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, body, h_scr[...])
    h_scr[...] = h

    @pl.when(s_idx == n_s - 1)
    def _final():
        hlast_ref[0] = h


def _fwd_call(dt, x, bmat, cmat, a, chunk: int, d_tile: int, interpret: bool,
              checkpoints: bool):
    bsz, s, d = x.shape
    st = bmat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    assert d % d_tile == 0, (d, d_tile)
    n_s = s // chunk
    grid = (bsz, d // d_tile, n_s)

    out_specs = [
        pl.BlockSpec((1, chunk, d_tile), lambda b, dd, ss: (b, ss, dd)),  # y
        pl.BlockSpec((1, d_tile, st), lambda b, dd, ss: (b, dd, 0)),  # h_final
    ]
    out_shape = [
        jax.ShapeDtypeStruct((bsz, s, d), x.dtype),
        jax.ShapeDtypeStruct((bsz, d, st), jnp.float32),
    ]
    if checkpoints:
        out_specs.append(
            pl.BlockSpec((1, 1, d_tile, st), lambda b, dd, ss: (b, ss, dd, 0))
        )
        out_shape.append(jax.ShapeDtypeStruct((bsz, n_s, d, st), jnp.float32))

    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, checkpoints=checkpoints),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d_tile), lambda b, dd, ss: (b, ss, dd)),  # dt
            pl.BlockSpec((1, chunk, d_tile), lambda b, dd, ss: (b, ss, dd)),  # x
            pl.BlockSpec((1, chunk, st), lambda b, dd, ss: (b, ss, 0)),  # B
            pl.BlockSpec((1, chunk, st), lambda b, dd, ss: (b, ss, 0)),  # C
            pl.BlockSpec((d_tile, st), lambda b, dd, ss: (dd, 0)),  # A
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((d_tile, st), jnp.float32)],
        interpret=interpret,
    )(dt, x, bmat, cmat, a)


def _bwd_kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, hstart_ref, dy_ref, dhfin_ref,
                ddt_ref, dx_ref, db_ref, dc_ref, da_ref,
                h_scr, g_scr, da_scr, *, chunk: int):
    """Reverse pass over one (batch row, d-tile, seq chunk) cell.

    Grid is ``(batch, seq chunk, d tile)`` with the seq axis REVERSED by
    the index maps (grid step ``ss`` touches chunk ``n_s - 1 - ss``) and
    the d-tile axis innermost so the dB/dC partial sums over d-tiles
    accumulate into a block that stays VMEM-resident between consecutive
    grid steps.  Per-(b, d-tile) reverse carries live in scratch slabs
    indexed by the d-tile id.
    """
    b_idx = pl.program_id(0)
    s_idx = pl.program_id(1)  # 0 == LAST seq chunk (reversed index maps)
    d_idx = pl.program_id(2)
    n_b = pl.num_programs(0)
    n_s = pl.num_programs(1)

    a = a_ref[...].astype(jnp.float32)  # (d_tile, st)
    st = a.shape[-1]

    # 1) recompute this chunk's states from the checkpoint:
    #    h_scr[i] = state BEFORE step i (h_scr[chunk] = state after the chunk)
    def fwd_body(i, h):
        h_scr[i] = h
        dt_i = dt_ref[0, i, :].astype(jnp.float32)
        x_i = x_ref[0, i, :].astype(jnp.float32)
        b_i = b_ref[0, i, :].astype(jnp.float32)
        decay = jnp.exp(dt_i[:, None] * a)
        return decay * h + (dt_i * x_i)[:, None] * b_i[None, :]

    h_last = jax.lax.fori_loop(
        0, chunk, fwd_body, hstart_ref[0, 0].astype(jnp.float32)
    )
    h_scr[chunk] = h_last

    # 2) reverse accumulation; g = dL/dh_t carried right-to-left
    @pl.when(s_idx == 0)
    def _init_g():
        g_scr[d_idx] = dhfin_ref[0].astype(jnp.float32)

    def bwd_body(i, carry):
        g, db_acc, dc_acc, da_acc = carry
        t = chunk - 1 - i
        dt_t = dt_ref[0, t, :].astype(jnp.float32)  # (d_tile,)
        x_t = x_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)  # (st,)
        c_t = c_ref[0, t, :].astype(jnp.float32)
        dy_t = dy_ref[0, t, :].astype(jnp.float32)  # (d_tile,)
        h_prev = h_scr[t]  # (d_tile, st)
        h_t = h_scr[t + 1]
        dc_acc = dc_acc.at[t].set(jnp.sum(h_t * dy_t[:, None], axis=0))
        g = g + dy_t[:, None] * c_t[None, :]
        decay = jnp.exp(dt_t[:, None] * a)
        gdec = g * h_prev * decay  # = dL/d(dt_t ⊗ a), chained through exp
        s_gb = jnp.sum(g * b_t[None, :], axis=1)  # (d_tile,) = dL/d(dt_t * x_t)
        ddt_ref[0, t, :] = jnp.sum(gdec * a, axis=1) + x_t * s_gb
        dx_ref[0, t, :] = dt_t * s_gb
        db_acc = db_acc.at[t].set(jnp.sum(g * (dt_t * x_t)[:, None], axis=0))
        da_acc = da_acc + dt_t[:, None] * gdec
        g = g * decay
        return g, db_acc, dc_acc, da_acc

    zeros_cs = jnp.zeros((chunk, st), jnp.float32)
    g, db_acc, dc_acc, da_acc = jax.lax.fori_loop(
        0, chunk, bwd_body, (g_scr[d_idx], zeros_cs, zeros_cs, jnp.zeros_like(a))
    )
    g_scr[d_idx] = g

    # dB/dC: partial sums over this d-tile; the (b, chunk) output block is
    # revisited consecutively as d_idx advances, so accumulate in place
    @pl.when(d_idx == 0)
    def _db_init():
        db_ref[0] = db_acc
        dc_ref[0] = dc_acc

    @pl.when(d_idx > 0)
    def _db_acc():
        db_ref[0] = db_ref[0] + db_acc
        dc_ref[0] = dc_ref[0] + dc_acc

    # dA: accumulated over batch AND seq in scratch, written once at the
    # final visit of this d-tile
    first = jnp.logical_and(b_idx == 0, s_idx == 0)

    @pl.when(first)
    def _da_init():
        da_scr[d_idx] = da_acc

    @pl.when(jnp.logical_not(first))
    def _da_acc():
        da_scr[d_idx] = da_scr[d_idx] + da_acc

    @pl.when(jnp.logical_and(b_idx == n_b - 1, s_idx == n_s - 1))
    def _da_out():
        da_ref[...] = da_scr[d_idx]


def _bwd_call(dt, x, bmat, cmat, a, hstart, dy, dhfin,
              chunk: int, d_tile: int, interpret: bool):
    bsz, s, d = x.shape
    st = bmat.shape[-1]
    n_s = s // chunk
    n_d = d // d_tile
    grid = (bsz, n_s, n_d)
    rev = lambda ss: n_s - 1 - ss  # noqa: E731 — seq chunks in reverse

    f32 = jnp.float32
    return pl.pallas_call(
        functools.partial(_bwd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d_tile), lambda b, ss, dd: (b, rev(ss), dd)),  # dt
            pl.BlockSpec((1, chunk, d_tile), lambda b, ss, dd: (b, rev(ss), dd)),  # x
            pl.BlockSpec((1, chunk, st), lambda b, ss, dd: (b, rev(ss), 0)),  # B
            pl.BlockSpec((1, chunk, st), lambda b, ss, dd: (b, rev(ss), 0)),  # C
            pl.BlockSpec((d_tile, st), lambda b, ss, dd: (dd, 0)),  # A
            pl.BlockSpec((1, 1, d_tile, st), lambda b, ss, dd: (b, rev(ss), dd, 0)),  # hstart
            pl.BlockSpec((1, chunk, d_tile), lambda b, ss, dd: (b, rev(ss), dd)),  # dy
            pl.BlockSpec((1, d_tile, st), lambda b, ss, dd: (b, dd, 0)),  # dhfin
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d_tile), lambda b, ss, dd: (b, rev(ss), dd)),  # ddt
            pl.BlockSpec((1, chunk, d_tile), lambda b, ss, dd: (b, rev(ss), dd)),  # dx
            pl.BlockSpec((1, chunk, st), lambda b, ss, dd: (b, rev(ss), 0)),  # dB
            pl.BlockSpec((1, chunk, st), lambda b, ss, dd: (b, rev(ss), 0)),  # dC
            pl.BlockSpec((d_tile, st), lambda b, ss, dd: (dd, 0)),  # dA
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, d), f32),
            jax.ShapeDtypeStruct((bsz, s, d), f32),
            jax.ShapeDtypeStruct((bsz, s, st), f32),
            jax.ShapeDtypeStruct((bsz, s, st), f32),
            jax.ShapeDtypeStruct((d, st), f32),
        ],
        scratch_shapes=[
            pltpu.VMEM((chunk + 1, d_tile, st), f32),  # recomputed chunk states
            pltpu.VMEM((n_d, d_tile, st), f32),  # g carry, one slab per d-tile
            pltpu.VMEM((n_d, d_tile, st), f32),  # dA accumulator per d-tile
        ],
        interpret=interpret,
    )(dt, x, bmat, cmat, a, hstart, dy, dhfin)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _ssm_scan(dt, x, bmat, cmat, a, chunk, d_tile, interpret):
    y, h_final = _fwd_call(dt, x, bmat, cmat, a, chunk, d_tile, interpret,
                           checkpoints=False)
    return y, h_final


def _ssm_scan_fwd(dt, x, bmat, cmat, a, chunk, d_tile, interpret):
    y, h_final, hstart = _fwd_call(dt, x, bmat, cmat, a, chunk, d_tile, interpret,
                                   checkpoints=True)
    return (y, h_final), (dt, x, bmat, cmat, a, hstart)


def _ssm_scan_bwd(chunk, d_tile, interpret, res, cts):
    dt, x, bmat, cmat, a, hstart = res
    dy, dhfin = cts
    ddt, dx, db, dc, da = _bwd_call(
        dt, x, bmat, cmat, a, hstart, dy, dhfin, chunk, d_tile, interpret
    )
    return (
        ddt.astype(dt.dtype),
        dx.astype(x.dtype),
        db.astype(bmat.dtype),
        dc.astype(cmat.dtype),
        da.astype(a.dtype),
    )


_ssm_scan.defvjp(_ssm_scan_fwd, _ssm_scan_bwd)


def _ssm_scan_launch(dt, x, bmat, cmat, a, chunk, d_tile, interpret):
    """Pad-to-chunk + fused-kernel dispatch (the guarded primary attempt)."""
    bsz, s, d = x.shape
    pad = (-s) % chunk
    if pad:
        widen = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))  # noqa: E731
        dt, x, bmat, cmat = widen(dt), widen(x), widen(bmat), widen(cmat)
    y, h_final = _ssm_scan(dt, x, bmat, cmat, a, chunk, d_tile, interpret)
    if pad:
        y = y[:, :s]
    return y, h_final


@kernel_contract(kind="scan", batched=True, differentiable=True)
def ssm_scan_pallas(
    dt: jax.Array,  # (B, S, D)
    x: jax.Array,
    bmat: jax.Array,  # (B, S, st)
    cmat: jax.Array,
    a: jax.Array,  # (D, st)
    *,
    chunk: int = 256,
    d_tile: int = 512,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused, differentiable scan; returns (y (B,S,D), h_final (B,D,st)).

    ``jax.grad`` through this runs the chunk-recompute backward kernel
    (see module docstring).  ``S`` need not divide ``chunk``: the tail is
    padded with identity steps (``dt = 0`` ⇒ ``decay = 1, upd = 0``), so
    ``h_final`` and the trimmed ``y`` — and their gradients — are exact.
    ``interpret=None`` resolves through ``REPRO_PALLAS_INTERPRET`` like
    every :mod:`repro.kernels.ops` wrapper.

    Eager calls route through guarded dispatch: preflight checks the scan
    VMEM model against the A005 budget, and a launch failure degrades to
    :func:`ssm_scan_ref` — the fp32 ``lax.scan`` twin the kernel is
    tested against (see ``docs/robustness.md``).  Traced calls (the
    training step) dispatch the kernel directly.
    """
    from repro.runtime import faults as _faults
    from repro.runtime import resilience as _res

    bsz, s, d = x.shape
    st = a.shape[-1]
    chunk = max(1, min(chunk, s))
    d_tile = max(1, min(d_tile, d))
    while d % d_tile:  # largest divisor of D at or below the requested tile
        d_tile -= 1
    itp = _interp(interpret)
    if not _res.guard_enabled() or _res.is_tracing(dt, x, bmat, cmat, a):
        return _ssm_scan_launch(dt, x, bmat, cmat, a, chunk, d_tile, itp)
    idx = _faults.next_index("ssm_scan_pallas")
    meta = {
        "n": s, "batch": bsz, "dtype": str(x.dtype), "seq": s, "d_model": d,
        "state": st, "chunk": chunk, "d_tile": d_tile,
    }
    return _res.guarded_call(
        "ssm_scan_pallas",
        [
            ("pallas-scan",
             lambda: _ssm_scan_launch(dt, x, bmat, cmat, a, chunk, d_tile, itp)),
            ("core-ref", lambda: ssm_scan_ref(dt, x, bmat, cmat, a)),
        ],
        index=idx,
        meta=meta,
    )


# primary public name (the kernel the training path differentiates through)
ssm_scan = ssm_scan_pallas


def fused_hbm_bytes(bsz: int, s: int, d: int, st: int, elem: int = 2) -> int:
    """Analytic HBM traffic of the fused kernel (for §Perf napkin math)."""
    return elem * (3 * bsz * s * d + 2 * bsz * s * st) + 4 * bsz * d * st


def bwd_hbm_bytes(bsz: int, s: int, d: int, st: int, chunk: int, elem: int = 2) -> int:
    """Analytic HBM traffic of the recompute backward: forward inputs + dy +
    checkpoints in, five gradients out."""
    fwd_in = elem * (3 * bsz * s * d + 2 * bsz * s * st)
    ckpt = 4 * bsz * (s // max(1, chunk)) * d * st
    grads_out = 4 * (2 * bsz * s * d + 2 * bsz * s * st + d * st)
    return fwd_in + ckpt + grads_out


def xla_scan_hbm_bytes(bsz: int, s: int, d: int, st: int, elem: int = 4) -> int:
    """Lower bound for the associative-scan path: 2 tensors (decay, upd) of
    (B,S,D,st) read+written per scan level."""
    import math

    levels = max(1, int(math.log2(max(2, s))))
    return elem * 2 * 2 * bsz * s * d * st * levels
