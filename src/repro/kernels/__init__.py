"""Pallas TPU kernels for the Merge Path hot spots (+ jnp oracles)."""

from . import ops, ref, tune
from .merge_path import (
    DEFAULT_ENGINE,
    DEFAULT_LEAF,
    DEFAULT_TILE,
    merge_batched_pallas,
    merge_batched_ragged_pallas,
    merge_kv_batched_pallas,
    merge_kv_batched_ragged_pallas,
    merge_kv_pallas,
    merge_pallas,
    sort_round_kv_pallas,
    sort_round_pallas,
)

__all__ = [
    "ops",
    "ref",
    "tune",
    "merge_pallas",
    "merge_kv_pallas",
    "merge_batched_pallas",
    "merge_kv_batched_pallas",
    "merge_batched_ragged_pallas",
    "merge_kv_batched_ragged_pallas",
    "sort_round_pallas",
    "sort_round_kv_pallas",
    "DEFAULT_TILE",
    "DEFAULT_LEAF",
    "DEFAULT_ENGINE",
]
