"""Pallas TPU kernels for the Merge Path hot spots (+ jnp oracles)."""

from . import ops, ref
from .merge_path import (
    DEFAULT_TILE,
    merge_batched_pallas,
    merge_batched_ragged_pallas,
    merge_kv_batched_pallas,
    merge_kv_batched_ragged_pallas,
    merge_kv_pallas,
    merge_pallas,
)

__all__ = [
    "ops",
    "ref",
    "merge_pallas",
    "merge_kv_pallas",
    "merge_batched_pallas",
    "merge_kv_batched_pallas",
    "merge_batched_ragged_pallas",
    "merge_kv_batched_ragged_pallas",
    "DEFAULT_TILE",
]
