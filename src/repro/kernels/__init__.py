"""Pallas TPU kernels for the Merge Path hot spots (+ jnp oracles)."""

from . import ops, ref
from .merge_path import merge_pallas, merge_kv_pallas, DEFAULT_TILE

__all__ = ["ops", "ref", "merge_pallas", "merge_kv_pallas", "DEFAULT_TILE"]
