"""(tile, leaf) selection for the hierarchical tile engine.

The two-level engine has two knobs: the output tile ``T`` (level-1
partition / VMEM working set) and the leaf width ``S`` (the only scale at
which quadratic merge-matrix work happens).  The sweet spot depends on
dtype and problem size, so ``kernels.ops`` resolves unspecified
``tile=None`` / ``leaf=None`` arguments through :func:`pick`, which
consults a small micro-bench table:

* ``DEFAULT_TABLE`` ships with the repo — measured with
  :func:`build_table` in interpret mode on the dev container (regenerate
  with ``python -m repro.kernels.tune``; on a real TPU run it once with
  ``REPRO_PALLAS_INTERPRET=0`` and commit the result).
* :func:`autotune` re-measures one ``(dtype, size)`` cell over a
  candidate grid and updates the in-process table, for callers whose
  workload is hot enough to warrant a startup sweep.

Keys are ``(dtype kind, log2-size bucket)``; lookups fall back to the
nearest measured bucket, then to ``(DEFAULT_TILE, DEFAULT_LEAF)``, so
:func:`pick` never fails.  Tiles are powers of two (the flat sort rounds
require ``tile | 2 * width``).
"""

from __future__ import annotations

import sys
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.telemetry import wall_seconds

from .merge_path import DEFAULT_LEAF, DEFAULT_TILE, _interp, merge_pallas

TILE_CANDIDATES = (128, 256, 512, 1024)
LEAF_CANDIDATES = (8, 16, 32, 64)

# (dtype kind, log2(total elements) bucket) -> (tile, leaf).
# Measured by build_table() in interpret mode on the CPU-only dev
# container (see module docstring); sparse on purpose — pick() snaps to
# the nearest bucket.
DEFAULT_TABLE: Dict[Tuple[str, int], Tuple[int, int]] = {
    ("f", 12): (512, 16),
    ("f", 15): (512, 8),
    ("f", 18): (512, 8),
    ("i", 12): (256, 8),
    ("i", 15): (1024, 8),
    ("i", 18): (1024, 8),
}

_TABLE: Dict[Tuple[str, int], Tuple[int, int]] = dict(DEFAULT_TABLE)


def _kind(dtype) -> str:
    """Collapse a dtype to the table's kind axis: 'i' (ints) or 'f'
    (floats — incl. bfloat16, whose numpy kind is 'V')."""
    k = jnp.dtype(dtype).kind
    return "i" if k in ("i", "u") else "f"


def _bucket(n: int) -> int:
    return max(8, min(22, int(round(np.log2(max(2, n))))))


def pick(n: int, dtype) -> Tuple[int, int]:
    """Best known ``(tile, leaf)`` for merging/sorting ``n`` total elements.

    Exact-bucket hit first, then the nearest measured bucket of the same
    dtype kind, then the module defaults.  Never larger than the problem:
    the tile is capped at the next power of two >= n so tiny inputs do
    not get a 1024-wide tile.
    """
    kind, b = _kind(dtype), _bucket(n)
    entry = _TABLE.get((kind, b))
    if entry is None:
        same_kind = [(abs(kb - b), kb) for (kk, kb) in _TABLE if kk == kind]
        if same_kind:
            entry = _TABLE[(kind, min(same_kind)[1])]
        else:
            entry = (DEFAULT_TILE, DEFAULT_LEAF)
    tile, leaf = entry
    cap = 1 << max(0, (max(1, n) - 1).bit_length())
    tile = min(tile, max(cap, min(TILE_CANDIDATES)))
    return tile, min(leaf, tile)


def _time_us(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = wall_seconds()
        jax.block_until_ready(fn(*args))
        ts.append((wall_seconds() - t0) * 1e6)
    return float(np.median(ts))


def _probe_pair(n: int, dtype):
    rng = np.random.default_rng(n)
    half = max(1, n // 2)
    if _kind(dtype) == "i":
        a = np.sort(rng.integers(-(2**30), 2**30, half)).astype(np.int32)
        b = np.sort(rng.integers(-(2**30), 2**30, half)).astype(np.int32)
    else:
        a = np.sort(rng.standard_normal(half)).astype(np.float32)
        b = np.sort(rng.standard_normal(half)).astype(np.float32)
    return jnp.asarray(a, dtype=dtype), jnp.asarray(b, dtype=dtype)


def autotune(
    n: int,
    dtype,
    *,
    tiles: Tuple[int, ...] = TILE_CANDIDATES,
    leaves: Tuple[int, ...] = LEAF_CANDIDATES,
    iters: int = 3,
    interpret: Optional[bool] = None,
    update_table: bool = True,
) -> Tuple[int, int]:
    """Measure the candidate ``(tile, leaf)`` grid on an ``n``-element
    hierarchical merge and return the fastest pair.

    The micro-bench is the keys-only 1-D merge (the kv and batched
    variants share the same tile body, so the optimum transfers).  With
    ``update_table`` (default) the result is written into the in-process
    table, so subsequent :func:`pick` calls in the same bucket use it.
    ``interpret=None`` follows ``REPRO_PALLAS_INTERPRET`` like every
    kernel wrapper, so regenerating the table on a real TPU
    (``REPRO_PALLAS_INTERPRET=0 python -m repro.kernels.tune``) measures
    compiled kernels, not the interpreter.
    """
    interpret = _interp(interpret)
    a, b = _probe_pair(n, dtype)
    best, best_us = None, float("inf")
    # the autotuner's job is exactly to launch candidates one by one
    for tile in tiles:  # lint: ok(L004)
        if tile > max(1024, n):  # a tile wider than the problem is noise
            continue
        for leaf in leaves:  # lint: ok(L004)
            if leaf > tile:
                continue
            fn = jax.jit(
                lambda x, y, t=tile, s=leaf: merge_pallas(
                    x, y, tile=t, leaf=s, engine="hier", interpret=interpret
                )
            )
            us = _time_us(fn, a, b, iters=iters)
            if us < best_us:
                best, best_us = (tile, leaf), us
    assert best is not None
    if update_table:
        _TABLE[(_kind(dtype), _bucket(n))] = best
    return best


def build_table(
    sizes: Tuple[int, ...] = (1 << 12, 1 << 15, 1 << 18),
    dtypes=(jnp.float32, jnp.int32),
    **kw,
) -> Dict[Tuple[str, int], Tuple[int, int]]:
    """Run :func:`autotune` over a (sizes x dtypes) grid; returns the table
    fragment (also installed in-process).  This is what produced
    ``DEFAULT_TABLE``."""
    out = {}
    for dtype in dtypes:
        for n in sizes:
            out[(_kind(dtype), _bucket(n))] = autotune(n, dtype, **kw)
    return out


def main() -> None:
    table = build_table()
    print("DEFAULT_TABLE: Dict[Tuple[str, int], Tuple[int, int]] = {")
    for k in sorted(table):
        print(f"    {k!r}: {table[k]!r},")
    print("}")


if __name__ == "__main__":
    sys.exit(main())
