"""Pallas TPU kernel for Merge Path — the paper's SPM, VMEM-tiled.

Mapping of the paper's cache-efficient Segmented Parallel Merge (Alg. 3)
onto the TPU memory hierarchy:

* the **cache** is VMEM; a segment is one grid step's working set;
* the per-segment window guarantee (Lemma 16: a T-output segment needs at
  most T consecutive inputs from each array) bounds every grid step to
  ``2*T`` input elements + ``T`` outputs staged through VMEM;
* the **partition phase** (Alg. 2's cross-diagonal binary searches) runs
  once, vectorized, *outside* the kernel and its results ride in as
  scalar-prefetch operands (SMEM) that the BlockSpec machinery and the
  kernel body use to slice dynamic input windows — the TPU analogue of
  the paper's "p cores independently compute their start points".

Inside a tile, two engines are available (``engine=`` on every wrapper):

* ``"hier"`` (default) — the **hierarchical two-level tile engine**.  The
  paper's partition idea is applied *again inside the tile* (the
  recursion Siebert & Träff's co-ranking makes explicit): a fixed-trip
  vectorized bisection over the tile's sub-diagonals (level 2 of the
  partition; ``repro.core.batched.window_intersections``) cuts the
  T-output tile into ``ceil(T/S)`` leaves of ``S`` outputs each
  (VPU-lane-aligned, default ``S = 32``), and only the ``(S, S)`` leaf
  materializes the paper's Merge Matrix to get cross-ranks.  Rank
  application is an O(T) gather driven by the leaf ranks plus the
  sub-partition offsets (no ``(T, T)`` one-hot).  Per-tile work drops
  from O(T^2) to O(T*S + T log T); quadratic work only ever happens at
  the fixed leaf size.
* ``"matrix"`` — the original single-level engine: materialize the full
  ``(T, T)`` Merge Matrix and apply ranks via a ``(T, T)`` one-hot
  masked sum.  Kept as the bit-exactness oracle for the hierarchical
  engine and as the benchmark baseline (``bench_tile_engine``).

Both engines share the masked/unmasked leaf-rank forms, so the ragged /
key-value length-masking guarantees (pads excluded from ranks by *index*,
never by comparing against the sentinel) carry through unchanged.

Output tiles are *exactly* T elements each (Corollary 7 — equal output
partitions is the whole point of the path partition), so the output uses
a plain blocked BlockSpec, aligned to the 128-lane VPU width.

Inputs stay in ``pl.ANY`` (compiler-chosen, HBM for large arrays) and the
kernel slices dynamic windows from them; on real hardware the production
variant would stage those windows via ``pltpu.make_async_copy`` into
double-buffered VMEM scratch — in interpret mode (this container is
CPU-only) the dynamic-slice form is the validated path.  The
hierarchical engine's leaf-window extraction and rank application use
vector gathers (``take_along_axis``-style); on hardware generations
without native VPU gather the leaf-scale one-hot form of the ``matrix``
engine at ``T = S`` is the fallback.
"""

from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.batched import (
    _as_lens,
    _mask_rows,
    diagonal_intersections_batched,
    diagonal_intersections_ragged,
    window_intersections,
)
from repro.core.merge_path import diagonal_intersections, max_sentinel

DEFAULT_TILE = 512
DEFAULT_LEAF = 32
DEFAULT_ENGINE = "hier"


def _env_interpret() -> bool:
    """Read REPRO_PALLAS_INTERPRET: '0'/'false'/'no'/'off' -> compiled,
    anything else (or unset) -> interpret mode (this container is
    CPU-only, so interpret is the safe default)."""
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1").strip().lower() not in (
        "0",
        "false",
        "no",
        "off",
    )


DEFAULT_INTERPRET: bool = _env_interpret()


def _interp(interpret: Optional[bool]) -> bool:
    return DEFAULT_INTERPRET if interpret is None else interpret


def _norm_leaf(tile: int, leaf: int) -> int:
    """Clamp the leaf width into [1, tile] (an S > T leaf is pure waste)."""
    return max(1, min(int(leaf), int(tile)))


# ---------------------------------------------------------------------------
# Single-level ("matrix") tile engine: the full (T, T) merge matrix
# ---------------------------------------------------------------------------


def _tile_ranks(wak: jax.Array, wbk: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Cross-ranks of two sorted windows = the tile's Merge Matrix, reduced.

    ``M[i, j] = (wa[i] > wb[j])`` is the paper's binary merge matrix
    restricted to the tile.  Row sums give how many B elements precede
    each A element; column sums of the complement (with ties going to A)
    give the symmetric count.  rank = own index + cross count.

    Sentinel pads rank like real elements here; that is exact for
    **keys-only** tiles (a pad tied with a sentinel-valued payload writes
    the same value), which is why the keys-only kernels keep this cheaper
    form.  Key-*value* tiles must distinguish pads from payloads — they
    use :func:`_tile_ranks_masked`.
    """
    t = wak.shape[0]
    iot = jnp.arange(t, dtype=jnp.int32)
    m = wak[:, None] > wbk[None, :]  # (T, T) merge matrix tile
    ra = iot + jnp.sum(m, axis=1, dtype=jnp.int32)  # A[i] after B[j] iff B[j] < A[i]
    rb = iot + jnp.sum(~m, axis=0, dtype=jnp.int32)  # B[j] after A[i] iff A[i] <= B[j]
    return ra, rb


def _tile_ranks_masked(
    wak: jax.Array, wbk: jax.Array, valid_a: jax.Array, valid_b: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Length-aware cross-ranks: only the windows' valid prefixes count.

    ``valid_a`` / ``valid_b`` are the number of real (non-pad) elements at
    the head of each window.  Pads are excluded from the cross counts by
    *index*, never by comparing against the sentinel, so payload keys
    equal to the sentinel (real ``+inf``, int ``iinfo.max``) rank exactly;
    pad entries themselves rank ``T`` (outside the tile, dropped).
    """
    t = wak.shape[0]
    iot = jnp.arange(t, dtype=jnp.int32)
    m = wak[:, None] > wbk[None, :]
    jvalid = iot[None, :] < valid_b
    ivalid = iot[:, None] < valid_a
    ra = iot + jnp.sum(m & jvalid, axis=1, dtype=jnp.int32)
    rb = iot + jnp.sum((~m) & ivalid, axis=0, dtype=jnp.int32)
    ra = jnp.where(iot < valid_a, ra, t)
    rb = jnp.where(iot < valid_b, rb, t)
    return ra, rb


def _permute_select(rank: jax.Array, window: jax.Array, t: int) -> jax.Array:
    """Apply the rank permutation: out[k] = window[i] where rank[i] == k.

    One-hot masked sum — a (T, T) select + reduce on the VPU, exact for
    all dtypes.  Ranks >= T fall outside this tile (consumed by a later
    one) and contribute nothing.
    """
    k = jnp.arange(t, dtype=jnp.int32)
    onehot = rank[:, None] == k[None, :]
    zero = jnp.zeros((), window.dtype)
    return jnp.sum(jnp.where(onehot, window[:, None], zero), axis=0)


def _permute_fill(rank: jax.Array, window: jax.Array, t: int) -> Tuple[jax.Array, jax.Array]:
    """Like :func:`_permute_select`, but also returns per-slot coverage."""
    k = jnp.arange(t, dtype=jnp.int32)
    onehot = rank[:, None] == k[None, :]
    zero = jnp.zeros((), window.dtype)
    val = jnp.sum(jnp.where(onehot, window[:, None], zero), axis=0)
    count = jnp.sum(onehot, axis=0, dtype=jnp.int32)
    return val, count


# ---------------------------------------------------------------------------
# Hierarchical two-level tile engine
# ---------------------------------------------------------------------------
#
# Level 1 (host side, unchanged): Alg. 2 over the *global* cross diagonals
# produces per-tile (a_start, b_start) scalar-prefetch tables.  Level 2
# (in-kernel, new): Alg. 2 again, over the tile's own sub-diagonals
# (0, S, 2S, ...), splits the T-output tile into leaves of S outputs —
# Lemma 16 applies recursively, so leaf l needs at most S consecutive
# elements of each window starting at its sub-partition point.  Only the
# (S, S) leaf computes cross-ranks via the merge matrix; ranks are applied
# with an O(T) gather (below), so the T^2 term of the single-level engine
# becomes T*S + T log T.


def _leaf_ranks(la: jax.Array, lb: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(S, S) merge-matrix cross-ranks for every leaf at once.

    ``la`` / ``lb`` are ``(L, S)`` stacked leaf windows.  Same math as
    :func:`_tile_ranks`, batched over the leaf axis: total work L*S^2 =
    T*S instead of T^2.
    """
    s = la.shape[1]
    iot = jnp.arange(s, dtype=jnp.int32)
    m = la[:, :, None] > lb[:, None, :]  # (L, S, S) leaf merge matrices
    ra = iot[None, :] + jnp.sum(m, axis=2, dtype=jnp.int32)
    rb = iot[None, :] + jnp.sum(~m, axis=1, dtype=jnp.int32)
    return ra, rb


def _leaf_ranks_masked(
    la: jax.Array, lb: jax.Array, valid_a: jax.Array, valid_b: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Length-aware leaf cross-ranks; ``valid_a``/``valid_b`` are ``(L,)``
    per-leaf valid prefix lengths.  Pads are excluded by index (never by
    sentinel comparison) exactly as in :func:`_tile_ranks_masked`; pad
    entries rank ``S`` (outside the leaf, dropped by the apply step)."""
    s = la.shape[1]
    iot = jnp.arange(s, dtype=jnp.int32)
    m = la[:, :, None] > lb[:, None, :]
    jvalid = iot[None, None, :] < valid_b[:, None, None]  # (L, 1, S)
    ivalid = iot[None, :, None] < valid_a[:, None, None]  # (L, S, 1)
    ra = iot[None, :] + jnp.sum(m & jvalid, axis=2, dtype=jnp.int32)
    rb = iot[None, :] + jnp.sum((~m) & ivalid, axis=1, dtype=jnp.int32)
    ra = jnp.where(iot[None, :] < valid_a[:, None], ra, s)
    rb = jnp.where(iot[None, :] < valid_b[:, None], rb, s)
    return ra, rb


def _hier_merge_window(
    wak: jax.Array,
    wbk: jax.Array,
    *,
    tile: int,
    leaf: int,
    wav: Optional[jax.Array] = None,
    wbv: Optional[jax.Array] = None,
    valid_a: Optional[jax.Array] = None,
    valid_b: Optional[jax.Array] = None,
    fill: bool = False,
) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Two-level merge of one tile's windows → ``(keys, values | None)``.

    1. **Level-2 split**: one fixed-trip vectorized bisection
       (:func:`repro.core.batched.window_intersections`) over the tile's
       sub-diagonals ``0, S, 2S, ...`` yields each leaf's sub-partition
       point ``(sa_l, sb_l)`` — O((T/S) log T).
    2. **Leaf ranks**: the ``(S, S)`` merge matrix of every leaf window
       pair, reduced to cross-ranks (masked when valid lengths are given)
       — O(T*S) total, the only quadratic-in-anything step.
    3. **O(T) gather apply**: for output slot ``j`` of a leaf,
       ``alpha[j] = |{i : ra[i] < j}|`` counts the A-side contributions
       among the first ``j`` leaf outputs (``ra`` is strictly increasing,
       so this is a rank lookup, computed leaf-locally); slot ``j`` is an
       A output iff ``ra[alpha[j]] == j``, and the element is *gathered*
       from ``la[alpha[j]]`` / ``lb[j - alpha[j]]`` — two O(T) gathers
       instead of the (T, T) one-hot.

    ``fill=True`` (ragged callers): slots past the windows' merged valid
    length get sentinel keys / zero values — bit-identical to the matrix
    engine's coverage-count fill.
    """
    s = _norm_leaf(tile, leaf)
    nleaf = -(-tile // s)  # ceil-div: last leaf may be short (trimmed below)
    masked = valid_a is not None
    kd = wak.dtype
    sent = max_sentinel(kd)
    diags = jnp.arange(nleaf, dtype=jnp.int32) * s
    if masked:
        valid_a = jnp.asarray(valid_a, jnp.int32)
        valid_b = jnp.asarray(valid_b, jnp.int32)
        total = valid_a + valid_b
        diags = jnp.minimum(diags, total)
        sa = window_intersections(wak, wbk, diags, valid_a, valid_b)
    else:
        sa = window_intersections(wak, wbk, diags)
    sb = diags - sa
    iot = jnp.arange(s, dtype=jnp.int32)
    ia = sa[:, None] + iot[None, :]  # (L, S) leaf-window gather indices
    ib = sb[:, None] + iot[None, :]
    # pad the tile windows by one leaf so leaf windows never overrun
    wakp = jnp.concatenate([wak, jnp.full((s,), sent, kd)])
    wbkp = jnp.concatenate([wbk, jnp.full((s,), sent, kd)])
    la = wakp[ia]
    lb = wbkp[ib]
    if masked:
        va = jnp.clip(valid_a - sa, 0, s)  # (L,) valid prefix of each leaf window
        vb = jnp.clip(valid_b - sb, 0, s)
        ra, _ = _leaf_ranks_masked(la, lb, va, vb)
    else:
        ra, _ = _leaf_ranks(la, lb)
    # Clamp to S before the alpha count: a valid element belonging to a
    # *later* leaf can rank past S, and pads rank exactly S — clamping
    # keeps the per-leaf rank vector sorted without changing any count
    # of ranks < j for j < S.
    ra_c = jnp.minimum(ra, s)
    jj = iot[None, :]  # output slot within leaf
    alpha = jnp.sum(ra_c[:, :, None] < iot[None, None, :], axis=1, dtype=jnp.int32)
    is_a = jnp.take_along_axis(ra_c, alpha, axis=1) == jj  # alpha[l, j] <= j < S: in bounds
    src_b = jj - alpha
    keys = jnp.where(
        is_a,
        jnp.take_along_axis(la, alpha, axis=1),
        jnp.take_along_axis(lb, src_b, axis=1),
    )
    out_k = keys.reshape(nleaf * s)[:tile]
    out_v = None
    if wav is not None:
        vd = wav.dtype
        wavp = jnp.concatenate([wav, jnp.zeros((s,), vd)])
        wbvp = jnp.concatenate([wbv, jnp.zeros((s,), vd)])
        vals = jnp.where(
            is_a,
            jnp.take_along_axis(wavp[ia], alpha, axis=1),
            jnp.take_along_axis(wbvp[ib], src_b, axis=1),
        )
        out_v = vals.reshape(nleaf * s)[:tile]
    if masked and fill:
        covered = jnp.arange(tile, dtype=jnp.int32) < total
        out_k = jnp.where(covered, out_k, sent)
        if out_v is not None:
            out_v = jnp.where(covered, out_v, jnp.zeros((), out_v.dtype))
    return out_k, out_v


def _tile_merge(
    wak: jax.Array,
    wbk: jax.Array,
    *,
    tile: int,
    leaf: int,
    engine: str,
    wav: Optional[jax.Array] = None,
    wbv: Optional[jax.Array] = None,
    valid_a: Optional[jax.Array] = None,
    valid_b: Optional[jax.Array] = None,
    fill: bool = False,
) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Engine dispatch for one tile: merge two windows into T outputs.

    ``engine="hier"`` → :func:`_hier_merge_window`;
    ``engine="matrix"`` → the (T, T) merge-matrix + one-hot path.
    Both produce bit-identical merged prefixes; ``fill`` additionally
    makes uncovered (past-the-valid-end) slots bit-identical (sentinel
    keys, zero values) for the ragged kernels whose padding is visible.
    """
    if engine == "hier":
        return _hier_merge_window(
            wak,
            wbk,
            tile=tile,
            leaf=leaf,
            wav=wav,
            wbv=wbv,
            valid_a=valid_a,
            valid_b=valid_b,
            fill=fill,
        )
    if engine != "matrix":
        raise ValueError(f"unknown tile engine {engine!r} (expected 'hier' or 'matrix')")
    if valid_a is None:
        ra, rb = _tile_ranks(wak, wbk)
    else:
        ra, rb = _tile_ranks_masked(wak, wbk, valid_a, valid_b)
    if fill:
        ka, ca = _permute_fill(ra, wak, tile)
        kb, cb = _permute_fill(rb, wbk, tile)
        keys = jnp.where(ca + cb > 0, ka + kb, max_sentinel(wak.dtype))
    else:
        keys = _permute_select(ra, wak, tile) + _permute_select(rb, wbk, tile)
    vals = None
    if wav is not None:
        vals = _permute_select(ra, wav, tile) + _permute_select(rb, wbv, tile)
    return keys, vals


# ---------------------------------------------------------------------------
# 1-D merges
# ---------------------------------------------------------------------------


def _merge_kernel(
    a_starts,  # scalar prefetch (SMEM): per-tile A start
    b_starts,  # scalar prefetch (SMEM): per-tile B start
    a_ref,  # (na + T,) sentinel-padded, memory_space=ANY
    b_ref,
    o_ref,  # (T,) VMEM output block
    *,
    tile: int,
    leaf: int,
    engine: str,
):
    t = pl.program_id(0)
    wa = a_ref[pl.ds(a_starts[t], tile)]
    wb = b_ref[pl.ds(b_starts[t], tile)]
    keys, _ = _tile_merge(wa, wb, tile=tile, leaf=leaf, engine=engine)
    o_ref[...] = keys


def _merge_kv_kernel(
    a_starts,
    b_starts,
    ak_ref,
    av_ref,
    bk_ref,
    bv_ref,
    ko_ref,
    vo_ref,
    *,
    tile: int,
    leaf: int,
    engine: str,
    na: int,
    nb: int,
):
    t = pl.program_id(0)
    a0 = a_starts[t]
    b0 = b_starts[t]
    wak = ak_ref[pl.ds(a0, tile)]
    wbk = bk_ref[pl.ds(b0, tile)]
    wav = av_ref[pl.ds(a0, tile)]
    wbv = bv_ref[pl.ds(b0, tile)]
    # Length-masked ranks: a window pad tied with a real sentinel-valued
    # key must not steal its slot and surface a zero value.
    valid_a = jnp.clip(na - a0, 0, tile)
    valid_b = jnp.clip(nb - b0, 0, tile)
    ko, vo = _tile_merge(
        wak, wbk, tile=tile, leaf=leaf, engine=engine,
        wav=wav, wbv=wbv, valid_a=valid_a, valid_b=valid_b,
    )
    ko_ref[...] = ko
    vo_ref[...] = vo


def _prepare(a, b, tile):
    """Common host-side partition phase (Alg. 2, vectorized)."""
    dtype = jnp.result_type(a, b)
    a = a.astype(dtype)
    b = b.astype(dtype)
    n = a.shape[0] + b.shape[0]
    nt = pl.cdiv(n, tile)
    diags = jnp.minimum(jnp.arange(nt, dtype=jnp.int32) * tile, n)
    a_starts = diagonal_intersections(a, b, diags).astype(jnp.int32)
    b_starts = diags - a_starts
    sent = max_sentinel(dtype)
    ap = jnp.concatenate([a, jnp.full((tile,), sent, dtype)])
    bp = jnp.concatenate([b, jnp.full((tile,), sent, dtype)])
    return ap, bp, a_starts, b_starts, n, nt, dtype


def merge_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    leaf: int = DEFAULT_LEAF,
    engine: str = DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Merge two sorted 1-D arrays with the Pallas SPM kernel."""
    ap, bp, a_starts, b_starts, n, nt, dtype = _prepare(a, b, tile)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((tile,), lambda t, *_: (t,)),
    )
    out = pl.pallas_call(
        functools.partial(_merge_kernel, tile=tile, leaf=_norm_leaf(tile, leaf), engine=engine),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nt * tile,), dtype),
        interpret=_interp(interpret),
    )(a_starts, b_starts, ap, bp)
    return out[:n]


def merge_kv_pallas(
    ak: jax.Array,
    av: jax.Array,
    bk: jax.Array,
    bv: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    leaf: int = DEFAULT_LEAF,
    engine: str = DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Stable key-value merge with the Pallas SPM kernel."""
    if av.shape != ak.shape or bv.shape != bk.shape:
        raise ValueError(
            f"value shapes must match key shapes: keys {ak.shape}/{bk.shape}, "
            f"values {av.shape}/{bv.shape}"
        )
    akp, bkp, a_starts, b_starts, n, nt, kd = _prepare(ak, bk, tile)
    vd = jnp.result_type(av, bv)
    avp = jnp.concatenate([av.astype(vd), jnp.zeros((tile,), vd)])
    bvp = jnp.concatenate([bv.astype(vd), jnp.zeros((tile,), vd)])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nt,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 4,
        out_specs=[
            pl.BlockSpec((tile,), lambda t, *_: (t,)),
            pl.BlockSpec((tile,), lambda t, *_: (t,)),
        ],
    )
    ko, vo = pl.pallas_call(
        functools.partial(
            _merge_kv_kernel,
            tile=tile,
            leaf=_norm_leaf(tile, leaf),
            engine=engine,
            na=ak.shape[0],
            nb=bk.shape[0],
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nt * tile,), kd),
            jax.ShapeDtypeStruct((nt * tile,), vd),
        ],
        interpret=_interp(interpret),
    )(a_starts, b_starts, akp, avp, bkp, bvp)
    return ko[:n], vo[:n]


# ---------------------------------------------------------------------------
# Batched merges: 2-D (batch, tile) grid
# ---------------------------------------------------------------------------
#
# The batched form runs B independent merges in ONE kernel launch.  The
# partition phase is a single fused Algorithm 2 pass over every (row,
# diagonal) pair (``diagonal_intersections_batched``), and its (B, nt)
# start tables ride into the kernel as scalar-prefetch operands.  Each
# (batch, tile) grid step reads its two starts from SMEM, slices its
# input windows from the row it owns, and writes exactly one (1, tile)
# output block — Corollary 7's equal output partition, now per row.
#
# Versus vmapping the 1-D kernel, this keeps ONE grid whose trailing
# (tile) axis is innermost, so consecutive grid steps walk consecutive
# output blocks of the same row (sequential HBM writes), and the
# partition bisection is shared across the whole batch instead of being
# re-run per lane.


def _merge_batched_kernel(
    a_starts,  # scalar prefetch (SMEM): (B, nt) per-(batch, tile) A starts
    b_starts,  # scalar prefetch (SMEM): (B, nt) per-(batch, tile) B starts
    a_ref,  # (B, na + T) sentinel-padded rows, memory_space=ANY
    b_ref,
    o_ref,  # (1, T) VMEM output block
    *,
    tile: int,
    leaf: int,
    engine: str,
):
    bi = pl.program_id(0)
    ti = pl.program_id(1)
    wa = a_ref[bi, pl.ds(a_starts[bi, ti], tile)]
    wb = b_ref[bi, pl.ds(b_starts[bi, ti], tile)]
    keys, _ = _tile_merge(wa, wb, tile=tile, leaf=leaf, engine=engine)
    o_ref[...] = keys[None, :]


def _merge_kv_batched_kernel(
    a_starts,
    b_starts,
    ak_ref,
    av_ref,
    bk_ref,
    bv_ref,
    ko_ref,
    vo_ref,
    *,
    tile: int,
    leaf: int,
    engine: str,
    na: int,
    nb: int,
):
    bi = pl.program_id(0)
    ti = pl.program_id(1)
    a0 = a_starts[bi, ti]
    b0 = b_starts[bi, ti]
    wak = ak_ref[bi, pl.ds(a0, tile)]
    wbk = bk_ref[bi, pl.ds(b0, tile)]
    wav = av_ref[bi, pl.ds(a0, tile)]
    wbv = bv_ref[bi, pl.ds(b0, tile)]
    valid_a = jnp.clip(na - a0, 0, tile)
    valid_b = jnp.clip(nb - b0, 0, tile)
    ko, vo = _tile_merge(
        wak, wbk, tile=tile, leaf=leaf, engine=engine,
        wav=wav, wbv=wbv, valid_a=valid_a, valid_b=valid_b,
    )
    ko_ref[...] = ko[None, :]
    vo_ref[...] = vo[None, :]


def _prepare_batched(a, b, tile):
    """Host-side partition phase for the batched kernel: one fused Alg. 2
    pass over all (row, diagonal) pairs."""
    if a.ndim != 2 or b.ndim != 2 or a.shape[0] != b.shape[0]:
        raise ValueError(f"expected (B, na) and (B, nb) with equal B, got {a.shape} and {b.shape}")
    dtype = jnp.result_type(a, b)
    a = a.astype(dtype)
    b = b.astype(dtype)
    bsz = a.shape[0]
    n = a.shape[1] + b.shape[1]
    nt = pl.cdiv(n, tile)
    diags = jnp.minimum(jnp.arange(nt, dtype=jnp.int32) * tile, n)
    a_starts = diagonal_intersections_batched(a, b, diags).astype(jnp.int32)  # (B, nt)
    b_starts = diags[None, :] - a_starts
    sent = max_sentinel(dtype)
    ap = jnp.concatenate([a, jnp.full((bsz, tile), sent, dtype)], axis=1)
    bp = jnp.concatenate([b, jnp.full((bsz, tile), sent, dtype)], axis=1)
    return ap, bp, a_starts, b_starts, bsz, n, nt, dtype


def merge_batched_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    leaf: int = DEFAULT_LEAF,
    engine: str = DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Merge ``B`` pairs of sorted rows in one 2-D-grid SPM kernel launch.

    ``a`` is ``(B, na)``, ``b`` is ``(B, nb)``, both row-sorted; returns
    ``(B, na + nb)`` where row ``r`` is the stable A-priority merge of
    ``a[r]`` and ``b[r]`` — bit-identical to ``vmap(merge)``.
    """
    ap, bp, a_starts, b_starts, bsz, n, nt, dtype = _prepare_batched(a, b, tile)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bsz, nt),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda bi, ti, *_: (bi, ti)),
    )
    out = pl.pallas_call(
        functools.partial(
            _merge_batched_kernel, tile=tile, leaf=_norm_leaf(tile, leaf), engine=engine
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, nt * tile), dtype),
        interpret=_interp(interpret),
    )(a_starts, b_starts, ap, bp)
    return out[:, :n]


def merge_kv_batched_pallas(
    ak: jax.Array,
    av: jax.Array,
    bk: jax.Array,
    bv: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    leaf: int = DEFAULT_LEAF,
    engine: str = DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Batched stable key-value merge on the 2-D-grid SPM kernel.

    Keys ``(B, na)``/``(B, nb)`` row-sorted; values carried along the same
    permutation.  Row ``r`` equals ``merge_kv`` of row ``r``.
    """
    if av.shape != ak.shape or bv.shape != bk.shape:
        raise ValueError(
            f"value shapes must match key shapes: keys {ak.shape}/{bk.shape}, "
            f"values {av.shape}/{bv.shape}"
        )
    akp, bkp, a_starts, b_starts, bsz, n, nt, kd = _prepare_batched(ak, bk, tile)
    vd = jnp.result_type(av, bv)
    avp = jnp.concatenate([av.astype(vd), jnp.zeros((bsz, tile), vd)], axis=1)
    bvp = jnp.concatenate([bv.astype(vd), jnp.zeros((bsz, tile), vd)], axis=1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bsz, nt),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 4,
        out_specs=[
            pl.BlockSpec((1, tile), lambda bi, ti, *_: (bi, ti)),
            pl.BlockSpec((1, tile), lambda bi, ti, *_: (bi, ti)),
        ],
    )
    ko, vo = pl.pallas_call(
        functools.partial(
            _merge_kv_batched_kernel,
            tile=tile,
            leaf=_norm_leaf(tile, leaf),
            engine=engine,
            na=ak.shape[1],
            nb=bk.shape[1],
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nt * tile), kd),
            jax.ShapeDtypeStruct((bsz, nt * tile), vd),
        ],
        interpret=_interp(interpret),
    )(a_starts, b_starts, akp, avp, bkp, bvp)
    return ko[:, :n], vo[:, :n]


# ---------------------------------------------------------------------------
# Ragged batched merges: per-row length tables via scalar prefetch
# ---------------------------------------------------------------------------
#
# The ragged form is the batched kernel with one addition: alongside the
# (B, nt) start tables, the per-row valid lengths ride in as scalar-
# prefetch operands (SMEM).  Each (batch, tile) grid step derives its
# windows' valid prefixes from the length tables and uses the length-
# masked rank form (at leaf scale for the hierarchical engine), so
# padding never shadows a payload and output slots past a row's merged
# length are filled with the sentinel.  The partition phase clamps every
# row's diagonals to that row's total valid length, so short rows simply
# run out of work early (their trailing tiles write pure sentinel
# blocks).


def _merge_batched_ragged_kernel(
    a_starts,  # scalar prefetch (SMEM): (B, nt) per-(batch, tile) A starts
    b_starts,
    a_lens,  # scalar prefetch (SMEM): (B,) per-row valid lengths
    b_lens,
    a_ref,  # (B, na + T) sentinel-masked + sentinel-padded rows
    b_ref,
    o_ref,  # (1, T) VMEM output block
    *,
    tile: int,
    leaf: int,
    engine: str,
):
    bi = pl.program_id(0)
    ti = pl.program_id(1)
    a0 = a_starts[bi, ti]
    b0 = b_starts[bi, ti]
    wa = a_ref[bi, pl.ds(a0, tile)]
    wb = b_ref[bi, pl.ds(b0, tile)]
    valid_a = jnp.clip(a_lens[bi] - a0, 0, tile)
    valid_b = jnp.clip(b_lens[bi] - b0, 0, tile)
    keys, _ = _tile_merge(
        wa, wb, tile=tile, leaf=leaf, engine=engine,
        valid_a=valid_a, valid_b=valid_b, fill=True,
    )
    o_ref[...] = keys[None, :]


def _merge_kv_batched_ragged_kernel(
    a_starts,
    b_starts,
    a_lens,
    b_lens,
    ak_ref,
    av_ref,
    bk_ref,
    bv_ref,
    ko_ref,
    vo_ref,
    *,
    tile: int,
    leaf: int,
    engine: str,
):
    bi = pl.program_id(0)
    ti = pl.program_id(1)
    a0 = a_starts[bi, ti]
    b0 = b_starts[bi, ti]
    wak = ak_ref[bi, pl.ds(a0, tile)]
    wbk = bk_ref[bi, pl.ds(b0, tile)]
    wav = av_ref[bi, pl.ds(a0, tile)]
    wbv = bv_ref[bi, pl.ds(b0, tile)]
    valid_a = jnp.clip(a_lens[bi] - a0, 0, tile)
    valid_b = jnp.clip(b_lens[bi] - b0, 0, tile)
    ko, vo = _tile_merge(
        wak, wbk, tile=tile, leaf=leaf, engine=engine,
        wav=wav, wbv=wbv, valid_a=valid_a, valid_b=valid_b, fill=True,
    )
    ko_ref[...] = ko[None, :]
    vo_ref[...] = vo[None, :]


def _prepare_batched_ragged(a, b, a_lens, b_lens, tile):
    """Partition phase for the ragged kernel: per-row clamped diagonals.

    Rows are sentinel-masked beyond their lengths (so windows stay
    sorted whatever the caller left in the padding), and each row's
    diagonals are clamped to its own total valid length — the bisection
    of ``diagonal_intersections_ragged`` then never probes padding.
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[0] != b.shape[0]:
        raise ValueError(f"expected (B, na) and (B, nb) with equal B, got {a.shape} and {b.shape}")
    dtype = jnp.result_type(a, b)
    bsz, na = a.shape
    nb = b.shape[1]
    a_lens = _as_lens(a_lens, bsz, na)
    b_lens = _as_lens(b_lens, bsz, nb)
    sent = max_sentinel(dtype)
    am = _mask_rows(a.astype(dtype), a_lens, sent)
    bm = _mask_rows(b.astype(dtype), b_lens, sent)
    n = na + nb
    nt = pl.cdiv(n, tile)
    row_total = (a_lens + b_lens)[:, None]  # (B, 1)
    diags = jnp.minimum(jnp.arange(nt, dtype=jnp.int32)[None, :] * tile, row_total)
    a_starts = diagonal_intersections_ragged(am, bm, a_lens, b_lens, diags).astype(jnp.int32)
    b_starts = diags - a_starts
    ap = jnp.concatenate([am, jnp.full((bsz, tile), sent, dtype)], axis=1)
    bp = jnp.concatenate([bm, jnp.full((bsz, tile), sent, dtype)], axis=1)
    return ap, bp, a_starts, b_starts, a_lens, b_lens, bsz, n, nt, dtype


def merge_batched_ragged_pallas(
    a: jax.Array,
    b: jax.Array,
    a_lens,
    b_lens,
    *,
    tile: int = DEFAULT_TILE,
    leaf: int = DEFAULT_LEAF,
    engine: str = DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Ragged batched merge on the 2-D ``(batch, tile)`` grid SPM kernel.

    Row ``r`` of the ``(B, na + nb)`` result starts with the stable
    A-priority merge of ``a[r, :a_lens[r]]`` and ``b[r, :b_lens[r]]``,
    followed by sentinel padding — bit-identical to
    :func:`repro.core.batched.merge_batched_ragged`.  The per-row length
    tables ride in as scalar-prefetch operands next to the start tables.
    """
    ap, bp, a_starts, b_starts, a_lens, b_lens, bsz, n, nt, dtype = _prepare_batched_ragged(
        a, b, a_lens, b_lens, tile
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(bsz, nt),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda bi, ti, *_: (bi, ti)),
    )
    out = pl.pallas_call(
        functools.partial(
            _merge_batched_ragged_kernel, tile=tile, leaf=_norm_leaf(tile, leaf), engine=engine
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, nt * tile), dtype),
        interpret=_interp(interpret),
    )(a_starts, b_starts, a_lens, b_lens, ap, bp)
    return out[:, :n]


def merge_kv_batched_ragged_pallas(
    ak: jax.Array,
    av: jax.Array,
    bk: jax.Array,
    bv: jax.Array,
    a_lens,
    b_lens,
    *,
    tile: int = DEFAULT_TILE,
    leaf: int = DEFAULT_LEAF,
    engine: str = DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Ragged batched key-value merge on the 2-D-grid SPM kernel.

    Bit-identical to :func:`repro.core.batched.merge_kv_batched_ragged`:
    merged valid pairs first, then sentinel keys with zero values.
    """
    if av.shape != ak.shape or bv.shape != bk.shape:
        raise ValueError(
            f"value shapes must match key shapes: keys {ak.shape}/{bk.shape}, "
            f"values {av.shape}/{bv.shape}"
        )
    akp, bkp, a_starts, b_starts, a_lens, b_lens, bsz, n, nt, kd = _prepare_batched_ragged(
        ak, bk, a_lens, b_lens, tile
    )
    vd = jnp.result_type(av, bv)
    avp = jnp.concatenate([av.astype(vd), jnp.zeros((bsz, tile), vd)], axis=1)
    bvp = jnp.concatenate([bv.astype(vd), jnp.zeros((bsz, tile), vd)], axis=1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(bsz, nt),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 4,
        out_specs=[
            pl.BlockSpec((1, tile), lambda bi, ti, *_: (bi, ti)),
            pl.BlockSpec((1, tile), lambda bi, ti, *_: (bi, ti)),
        ],
    )
    ko, vo = pl.pallas_call(
        functools.partial(
            _merge_kv_batched_ragged_kernel, tile=tile, leaf=_norm_leaf(tile, leaf), engine=engine
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nt * tile), kd),
            jax.ShapeDtypeStruct((bsz, nt * tile), vd),
        ],
        interpret=_interp(interpret),
    )(a_starts, b_starts, a_lens, b_lens, akp, avp, bkp, bvp)
    return ko[:, :n], vo[:, :n]


# ---------------------------------------------------------------------------
# Flat merge-sort rounds: the padded buffer lives across the whole sort
# ---------------------------------------------------------------------------
#
# ``kernels.ops.sort``/``sort_kv`` used to re-concatenate a (rows, tile)
# sentinel block onto BOTH run arrays every round (inside
# ``_prepare_batched``) — 2 extra allocations plus a full copy of the
# data per round.  The flat round kernel removes that: the sort keeps ONE
# flat buffer of ``m + tile`` elements (``m`` = pow2-padded data, tail =
# ``tile`` sentinels, built once per sort), run pairs are addressed by
# *flat* offsets riding in as scalar-prefetch tables, and window overrun
# into a neighboring run is excluded by the length-masked rank form
# (valid counts derived in-kernel from the static run width) instead of
# by padding.  The sentinel tail of the output buffer is re-written by
# one dedicated trailing grid step, so the buffer never round-trips
# through a host-side concatenate between rounds.


def _sort_round_kernel(
    fa,  # scalar prefetch (SMEM): (ntiles + 1,) flat A-window starts
    fb,  # scalar prefetch (SMEM): (ntiles + 1,) flat B-window starts
    x_ref,  # (m + tile,) flat keys, memory_space=ANY
    o_ref,  # (tile,) VMEM output block
    *,
    width: int,
    tile: int,
    leaf: int,
    engine: str,
    tiles_per_pair: int,
    n_data_tiles: int,
):
    s_id = pl.program_id(0)

    @pl.when(s_id < n_data_tiles)
    def _():
        pair = s_id // tiles_per_pair
        base = pair * (2 * width)
        a0 = fa[s_id] - base
        b0 = fb[s_id] - base - width
        wa = x_ref[pl.ds(fa[s_id], tile)]
        wb = x_ref[pl.ds(fb[s_id], tile)]
        # masked ranks: overrun past a run's width reads the *neighbor*
        # run (flat layout) — excluded by index, exactly like padding
        valid_a = jnp.clip(width - a0, 0, tile)
        valid_b = jnp.clip(width - b0, 0, tile)
        keys, _ = _tile_merge(
            wa, wb, tile=tile, leaf=leaf, engine=engine,
            valid_a=valid_a, valid_b=valid_b,
        )
        o_ref[...] = keys

    @pl.when(s_id >= n_data_tiles)
    def _():
        o_ref[...] = jnp.full((tile,), max_sentinel(x_ref.dtype), x_ref.dtype)


def _sort_round_kv_kernel(
    fa,
    fb,
    k_ref,
    v_ref,
    ko_ref,
    vo_ref,
    *,
    width: int,
    tile: int,
    leaf: int,
    engine: str,
    tiles_per_pair: int,
    n_data_tiles: int,
):
    s_id = pl.program_id(0)

    @pl.when(s_id < n_data_tiles)
    def _():
        pair = s_id // tiles_per_pair
        base = pair * (2 * width)
        a0 = fa[s_id] - base
        b0 = fb[s_id] - base - width
        wak = k_ref[pl.ds(fa[s_id], tile)]
        wbk = k_ref[pl.ds(fb[s_id], tile)]
        wav = v_ref[pl.ds(fa[s_id], tile)]
        wbv = v_ref[pl.ds(fb[s_id], tile)]
        valid_a = jnp.clip(width - a0, 0, tile)
        valid_b = jnp.clip(width - b0, 0, tile)
        ko, vo = _tile_merge(
            wak, wbk, tile=tile, leaf=leaf, engine=engine,
            wav=wav, wbv=wbv, valid_a=valid_a, valid_b=valid_b,
        )
        ko_ref[...] = ko
        vo_ref[...] = vo

    @pl.when(s_id >= n_data_tiles)
    def _():
        ko_ref[...] = jnp.full((tile,), max_sentinel(k_ref.dtype), k_ref.dtype)
        vo_ref[...] = jnp.zeros((tile,), v_ref.dtype)


def _sort_round_starts(xf, m, width, tile):
    """Flat scalar-prefetch tables for one sort round (plus the tail entry)."""
    npairs = m // (2 * width)
    tpp = (2 * width) // tile
    runs = xf[:m].reshape(npairs, 2 * width)
    diags = jnp.arange(tpp, dtype=jnp.int32) * tile
    a0 = diagonal_intersections_batched(runs[:, :width], runs[:, width:], diags).astype(jnp.int32)
    b0 = diags[None, :] - a0
    base = (jnp.arange(npairs, dtype=jnp.int32) * (2 * width))[:, None]
    fa = (base + a0).reshape(-1)
    fb = (base + width + b0).reshape(-1)
    # the sentinel-tail grid step still *addresses* the tables: give it a
    # safe in-bounds entry
    zero = jnp.zeros((1,), jnp.int32)
    return jnp.concatenate([fa, zero]), jnp.concatenate([fb, zero]), npairs * tpp, tpp


def sort_round_pallas(
    xf: jax.Array,
    width: int,
    *,
    tile: int = DEFAULT_TILE,
    leaf: int = DEFAULT_LEAF,
    engine: str = DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """One bottom-up merge-sort round on the flat padded layout.

    ``xf`` is ``(m + tile,)``: ``m`` (a power of two, a multiple of
    ``2 * width``; ``tile`` must divide ``2 * width``) data elements
    holding sorted runs of ``width``, then ``tile`` sentinels.  Returns
    the same layout with runs of ``2 * width`` — call repeatedly to sort.
    """
    m = xf.shape[0] - tile
    fa, fb, ndata, tpp = _sort_round_starts(xf, m, width, tile)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(ndata + 1,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((tile,), lambda s, *_: (s,)),
    )
    return pl.pallas_call(
        functools.partial(
            _sort_round_kernel,
            width=width,
            tile=tile,
            leaf=_norm_leaf(tile, leaf),
            engine=engine,
            tiles_per_pair=tpp,
            n_data_tiles=ndata,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m + tile,), xf.dtype),
        interpret=_interp(interpret),
    )(fa, fb, xf)


def sort_round_kv_pallas(
    kf: jax.Array,
    vf: jax.Array,
    width: int,
    *,
    tile: int = DEFAULT_TILE,
    leaf: int = DEFAULT_LEAF,
    engine: str = DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Key-value :func:`sort_round_pallas` (values: zero-filled tail)."""
    m = kf.shape[0] - tile
    fa, fb, ndata, tpp = _sort_round_starts(kf, m, width, tile)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(ndata + 1,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 2,
        out_specs=[
            pl.BlockSpec((tile,), lambda s, *_: (s,)),
            pl.BlockSpec((tile,), lambda s, *_: (s,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _sort_round_kv_kernel,
            width=width,
            tile=tile,
            leaf=_norm_leaf(tile, leaf),
            engine=engine,
            tiles_per_pair=tpp,
            n_data_tiles=ndata,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((m + tile,), kf.dtype),
            jax.ShapeDtypeStruct((m + tile,), vf.dtype),
        ],
        interpret=_interp(interpret),
    )(fa, fb, kf, vf)
