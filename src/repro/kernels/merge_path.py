"""Pallas TPU kernel for Merge Path — the paper's SPM, VMEM-tiled.

Mapping of the paper's cache-efficient Segmented Parallel Merge (Alg. 3)
onto the TPU memory hierarchy:

* the **cache** is VMEM; a segment is one grid step's working set;
* the per-segment window guarantee (Lemma 16: a T-output segment needs at
  most T consecutive inputs from each array) bounds every grid step to
  ``2*T`` input elements + ``T`` outputs staged through VMEM;
* the **partition phase** (Alg. 2's cross-diagonal binary searches) runs
  once, vectorized, *outside* the kernel and its results ride in as
  scalar-prefetch operands (SMEM) that the BlockSpec machinery and the
  kernel body use to slice dynamic input windows — the TPU analogue of
  the paper's "p cores independently compute their start points";
* the per-tile merge materializes the paper's **Merge Matrix** for the
  tile (T x T comparisons) and reduces it to cross-ranks.  On a CPU the
  paper rightly avoids ever materializing M; on a TPU, VPU compare+reduce
  throughput makes the T^2 tile matrix the cheap, branch-free choice.
  Ranks are then applied as a one-hot permutation (masked sum — exact for
  every dtype incl. int32; for f32/bf16 an MXU ``dot`` with the one-hot
  matrix is equivalent).

Output tiles are *exactly* T elements each (Corollary 7 — equal output
partitions is the whole point of the path partition), so the output uses
a plain blocked BlockSpec, aligned to the 128-lane VPU width.

Inputs stay in ``pl.ANY`` (compiler-chosen, HBM for large arrays) and the
kernel slices dynamic windows from them; on real hardware the production
variant would stage those windows via ``pltpu.make_async_copy`` into
double-buffered VMEM scratch — in interpret mode (this container is
CPU-only) the dynamic-slice form is the validated path.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.merge_path import diagonal_intersections, max_sentinel

DEFAULT_TILE = 512


def _tile_ranks(wak: jax.Array, wbk: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Cross-ranks of two sorted windows = the tile's Merge Matrix, reduced.

    ``M[i, j] = (wa[i] > wb[j])`` is the paper's binary merge matrix
    restricted to the tile.  Row sums give how many B elements precede
    each A element; column sums of the complement (with ties going to A)
    give the symmetric count.  rank = own index + cross count.
    """
    t = wak.shape[0]
    iot = jnp.arange(t, dtype=jnp.int32)
    m = wak[:, None] > wbk[None, :]  # (T, T) merge matrix tile
    ra = iot + jnp.sum(m, axis=1, dtype=jnp.int32)  # A[i] after B[j] iff B[j] < A[i]
    rb = iot + jnp.sum(~m, axis=0, dtype=jnp.int32)  # B[j] after A[i] iff A[i] <= B[j]
    return ra, rb


def _permute_select(rank: jax.Array, window: jax.Array, t: int) -> jax.Array:
    """Apply the rank permutation: out[k] = window[i] where rank[i] == k.

    One-hot masked sum — a (T, T) select + reduce on the VPU, exact for
    all dtypes.  Ranks >= T fall outside this tile (consumed by a later
    one) and contribute nothing.
    """
    k = jnp.arange(t, dtype=jnp.int32)
    onehot = rank[:, None] == k[None, :]
    zero = jnp.zeros((), window.dtype)
    return jnp.sum(jnp.where(onehot, window[:, None], zero), axis=0)


def _merge_kernel(
    a_starts,  # scalar prefetch (SMEM): per-tile A start
    b_starts,  # scalar prefetch (SMEM): per-tile B start
    a_ref,  # (na + T,) sentinel-padded, memory_space=ANY
    b_ref,
    o_ref,  # (T,) VMEM output block
    *,
    tile: int,
):
    t = pl.program_id(0)
    a0 = a_starts[t]
    b0 = b_starts[t]
    wa = a_ref[pl.ds(a0, tile)]
    wb = b_ref[pl.ds(b0, tile)]
    ra, rb = _tile_ranks(wa, wb)
    o_ref[...] = _permute_select(ra, wa, tile) + _permute_select(rb, wb, tile)


def _merge_kv_kernel(
    a_starts,
    b_starts,
    ak_ref,
    av_ref,
    bk_ref,
    bv_ref,
    ko_ref,
    vo_ref,
    *,
    tile: int,
):
    t = pl.program_id(0)
    a0 = a_starts[t]
    b0 = b_starts[t]
    wak = ak_ref[pl.ds(a0, tile)]
    wbk = bk_ref[pl.ds(b0, tile)]
    wav = av_ref[pl.ds(a0, tile)]
    wbv = bv_ref[pl.ds(b0, tile)]
    ra, rb = _tile_ranks(wak, wbk)
    ko_ref[...] = _permute_select(ra, wak, tile) + _permute_select(rb, wbk, tile)
    vo_ref[...] = _permute_select(ra, wav, tile) + _permute_select(rb, wbv, tile)


def _prepare(a, b, tile):
    """Common host-side partition phase (Alg. 2, vectorized)."""
    dtype = jnp.result_type(a, b)
    a = a.astype(dtype)
    b = b.astype(dtype)
    n = a.shape[0] + b.shape[0]
    nt = pl.cdiv(n, tile)
    diags = jnp.minimum(jnp.arange(nt, dtype=jnp.int32) * tile, n)
    a_starts = diagonal_intersections(a, b, diags).astype(jnp.int32)
    b_starts = diags - a_starts
    sent = max_sentinel(dtype)
    ap = jnp.concatenate([a, jnp.full((tile,), sent, dtype)])
    bp = jnp.concatenate([b, jnp.full((tile,), sent, dtype)])
    return ap, bp, a_starts, b_starts, n, nt, dtype


def merge_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jax.Array:
    """Merge two sorted 1-D arrays with the Pallas SPM kernel."""
    ap, bp, a_starts, b_starts, n, nt, dtype = _prepare(a, b, tile)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((tile,), lambda t, *_: (t,)),
    )
    out = pl.pallas_call(
        functools.partial(_merge_kernel, tile=tile),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nt * tile,), dtype),
        interpret=interpret,
    )(a_starts, b_starts, ap, bp)
    return out[:n]


def merge_kv_pallas(
    ak: jax.Array,
    av: jax.Array,
    bk: jax.Array,
    bv: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Stable key-value merge with the Pallas SPM kernel."""
    akp, bkp, a_starts, b_starts, n, nt, kd = _prepare(ak, bk, tile)
    vd = jnp.result_type(av, bv)
    avp = jnp.concatenate([av.astype(vd), jnp.zeros((tile,), vd)])
    bvp = jnp.concatenate([bv.astype(vd), jnp.zeros((tile,), vd)])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nt,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 4,
        out_specs=[
            pl.BlockSpec((tile,), lambda t, *_: (t,)),
            pl.BlockSpec((tile,), lambda t, *_: (t,)),
        ],
    )
    ko, vo = pl.pallas_call(
        functools.partial(_merge_kv_kernel, tile=tile),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nt * tile,), kd),
            jax.ShapeDtypeStruct((nt * tile,), vd),
        ],
        interpret=interpret,
    )(a_starts, b_starts, akp, avp, bkp, bvp)
    return ko[:n], vo[:n]
