"""Pallas TPU kernel for Merge Path — the paper's SPM, VMEM-tiled.

Mapping of the paper's cache-efficient Segmented Parallel Merge (Alg. 3)
onto the TPU memory hierarchy:

* the **cache** is VMEM; a segment is one grid step's working set;
* the per-segment window guarantee (Lemma 16: a T-output segment needs at
  most T consecutive inputs from each array) bounds every grid step to
  ``2*T`` input elements + ``T`` outputs staged through VMEM;
* the **partition phase** (Alg. 2's cross-diagonal binary searches) runs
  once, vectorized, *outside* the kernel and its results ride in as
  scalar-prefetch operands (SMEM) that the BlockSpec machinery and the
  kernel body use to slice dynamic input windows — the TPU analogue of
  the paper's "p cores independently compute their start points";
* the per-tile merge materializes the paper's **Merge Matrix** for the
  tile (T x T comparisons) and reduces it to cross-ranks.  On a CPU the
  paper rightly avoids ever materializing M; on a TPU, VPU compare+reduce
  throughput makes the T^2 tile matrix the cheap, branch-free choice.
  Ranks are then applied as a one-hot permutation (masked sum — exact for
  every dtype incl. int32; for f32/bf16 an MXU ``dot`` with the one-hot
  matrix is equivalent).

Output tiles are *exactly* T elements each (Corollary 7 — equal output
partitions is the whole point of the path partition), so the output uses
a plain blocked BlockSpec, aligned to the 128-lane VPU width.

Inputs stay in ``pl.ANY`` (compiler-chosen, HBM for large arrays) and the
kernel slices dynamic windows from them; on real hardware the production
variant would stage those windows via ``pltpu.make_async_copy`` into
double-buffered VMEM scratch — in interpret mode (this container is
CPU-only) the dynamic-slice form is the validated path.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.batched import (
    _as_lens,
    _mask_rows,
    diagonal_intersections_batched,
    diagonal_intersections_ragged,
)
from repro.core.merge_path import diagonal_intersections, max_sentinel

DEFAULT_TILE = 512


def _tile_ranks(wak: jax.Array, wbk: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Cross-ranks of two sorted windows = the tile's Merge Matrix, reduced.

    ``M[i, j] = (wa[i] > wb[j])`` is the paper's binary merge matrix
    restricted to the tile.  Row sums give how many B elements precede
    each A element; column sums of the complement (with ties going to A)
    give the symmetric count.  rank = own index + cross count.

    Sentinel pads rank like real elements here; that is exact for
    **keys-only** tiles (a pad tied with a sentinel-valued payload writes
    the same value), which is why the keys-only kernels keep this cheaper
    form.  Key-*value* tiles must distinguish pads from payloads — they
    use :func:`_tile_ranks_masked`.
    """
    t = wak.shape[0]
    iot = jnp.arange(t, dtype=jnp.int32)
    m = wak[:, None] > wbk[None, :]  # (T, T) merge matrix tile
    ra = iot + jnp.sum(m, axis=1, dtype=jnp.int32)  # A[i] after B[j] iff B[j] < A[i]
    rb = iot + jnp.sum(~m, axis=0, dtype=jnp.int32)  # B[j] after A[i] iff A[i] <= B[j]
    return ra, rb


def _tile_ranks_masked(
    wak: jax.Array, wbk: jax.Array, valid_a: jax.Array, valid_b: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Length-aware cross-ranks: only the windows' valid prefixes count.

    ``valid_a`` / ``valid_b`` are the number of real (non-pad) elements at
    the head of each window.  Pads are excluded from the cross counts by
    *index*, never by comparing against the sentinel, so payload keys
    equal to the sentinel (real ``+inf``, int ``iinfo.max``) rank exactly;
    pad entries themselves rank ``T`` (outside the tile, dropped).
    """
    t = wak.shape[0]
    iot = jnp.arange(t, dtype=jnp.int32)
    m = wak[:, None] > wbk[None, :]
    jvalid = iot[None, :] < valid_b
    ivalid = iot[:, None] < valid_a
    ra = iot + jnp.sum(m & jvalid, axis=1, dtype=jnp.int32)
    rb = iot + jnp.sum((~m) & ivalid, axis=0, dtype=jnp.int32)
    ra = jnp.where(iot < valid_a, ra, t)
    rb = jnp.where(iot < valid_b, rb, t)
    return ra, rb


def _permute_select(rank: jax.Array, window: jax.Array, t: int) -> jax.Array:
    """Apply the rank permutation: out[k] = window[i] where rank[i] == k.

    One-hot masked sum — a (T, T) select + reduce on the VPU, exact for
    all dtypes.  Ranks >= T fall outside this tile (consumed by a later
    one) and contribute nothing.
    """
    k = jnp.arange(t, dtype=jnp.int32)
    onehot = rank[:, None] == k[None, :]
    zero = jnp.zeros((), window.dtype)
    return jnp.sum(jnp.where(onehot, window[:, None], zero), axis=0)


def _merge_kernel(
    a_starts,  # scalar prefetch (SMEM): per-tile A start
    b_starts,  # scalar prefetch (SMEM): per-tile B start
    a_ref,  # (na + T,) sentinel-padded, memory_space=ANY
    b_ref,
    o_ref,  # (T,) VMEM output block
    *,
    tile: int,
):
    t = pl.program_id(0)
    a0 = a_starts[t]
    b0 = b_starts[t]
    wa = a_ref[pl.ds(a0, tile)]
    wb = b_ref[pl.ds(b0, tile)]
    ra, rb = _tile_ranks(wa, wb)
    o_ref[...] = _permute_select(ra, wa, tile) + _permute_select(rb, wb, tile)


def _merge_kv_kernel(
    a_starts,
    b_starts,
    ak_ref,
    av_ref,
    bk_ref,
    bv_ref,
    ko_ref,
    vo_ref,
    *,
    tile: int,
    na: int,
    nb: int,
):
    t = pl.program_id(0)
    a0 = a_starts[t]
    b0 = b_starts[t]
    wak = ak_ref[pl.ds(a0, tile)]
    wbk = bk_ref[pl.ds(b0, tile)]
    wav = av_ref[pl.ds(a0, tile)]
    wbv = bv_ref[pl.ds(b0, tile)]
    # Length-masked ranks: a window pad tied with a real sentinel-valued
    # key must not steal its slot and surface a zero value.
    valid_a = jnp.clip(na - a0, 0, tile)
    valid_b = jnp.clip(nb - b0, 0, tile)
    ra, rb = _tile_ranks_masked(wak, wbk, valid_a, valid_b)
    ko_ref[...] = _permute_select(ra, wak, tile) + _permute_select(rb, wbk, tile)
    vo_ref[...] = _permute_select(ra, wav, tile) + _permute_select(rb, wbv, tile)


def _prepare(a, b, tile):
    """Common host-side partition phase (Alg. 2, vectorized)."""
    dtype = jnp.result_type(a, b)
    a = a.astype(dtype)
    b = b.astype(dtype)
    n = a.shape[0] + b.shape[0]
    nt = pl.cdiv(n, tile)
    diags = jnp.minimum(jnp.arange(nt, dtype=jnp.int32) * tile, n)
    a_starts = diagonal_intersections(a, b, diags).astype(jnp.int32)
    b_starts = diags - a_starts
    sent = max_sentinel(dtype)
    ap = jnp.concatenate([a, jnp.full((tile,), sent, dtype)])
    bp = jnp.concatenate([b, jnp.full((tile,), sent, dtype)])
    return ap, bp, a_starts, b_starts, n, nt, dtype


def merge_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jax.Array:
    """Merge two sorted 1-D arrays with the Pallas SPM kernel."""
    ap, bp, a_starts, b_starts, n, nt, dtype = _prepare(a, b, tile)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((tile,), lambda t, *_: (t,)),
    )
    out = pl.pallas_call(
        functools.partial(_merge_kernel, tile=tile),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nt * tile,), dtype),
        interpret=interpret,
    )(a_starts, b_starts, ap, bp)
    return out[:n]


def merge_kv_pallas(
    ak: jax.Array,
    av: jax.Array,
    bk: jax.Array,
    bv: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Stable key-value merge with the Pallas SPM kernel."""
    if av.shape != ak.shape or bv.shape != bk.shape:
        raise ValueError(
            f"value shapes must match key shapes: keys {ak.shape}/{bk.shape}, "
            f"values {av.shape}/{bv.shape}"
        )
    akp, bkp, a_starts, b_starts, n, nt, kd = _prepare(ak, bk, tile)
    vd = jnp.result_type(av, bv)
    avp = jnp.concatenate([av.astype(vd), jnp.zeros((tile,), vd)])
    bvp = jnp.concatenate([bv.astype(vd), jnp.zeros((tile,), vd)])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nt,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 4,
        out_specs=[
            pl.BlockSpec((tile,), lambda t, *_: (t,)),
            pl.BlockSpec((tile,), lambda t, *_: (t,)),
        ],
    )
    ko, vo = pl.pallas_call(
        functools.partial(_merge_kv_kernel, tile=tile, na=ak.shape[0], nb=bk.shape[0]),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nt * tile,), kd),
            jax.ShapeDtypeStruct((nt * tile,), vd),
        ],
        interpret=interpret,
    )(a_starts, b_starts, akp, avp, bkp, bvp)
    return ko[:n], vo[:n]


# ---------------------------------------------------------------------------
# Batched merges: 2-D (batch, tile) grid
# ---------------------------------------------------------------------------
#
# The batched form runs B independent merges in ONE kernel launch.  The
# partition phase is a single fused Algorithm 2 pass over every (row,
# diagonal) pair (``diagonal_intersections_batched``), and its (B, nt)
# start tables ride into the kernel as scalar-prefetch operands.  Each
# (batch, tile) grid step reads its two starts from SMEM, slices its
# input windows from the row it owns, and writes exactly one (1, tile)
# output block — Corollary 7's equal output partition, now per row.
#
# Versus vmapping the 1-D kernel, this keeps ONE grid whose trailing
# (tile) axis is innermost, so consecutive grid steps walk consecutive
# output blocks of the same row (sequential HBM writes), and the
# partition bisection is shared across the whole batch instead of being
# re-run per lane.


def _merge_batched_kernel(
    a_starts,  # scalar prefetch (SMEM): (B, nt) per-(batch, tile) A starts
    b_starts,  # scalar prefetch (SMEM): (B, nt) per-(batch, tile) B starts
    a_ref,  # (B, na + T) sentinel-padded rows, memory_space=ANY
    b_ref,
    o_ref,  # (1, T) VMEM output block
    *,
    tile: int,
):
    bi = pl.program_id(0)
    ti = pl.program_id(1)
    a0 = a_starts[bi, ti]
    b0 = b_starts[bi, ti]
    wa = a_ref[bi, pl.ds(a0, tile)]
    wb = b_ref[bi, pl.ds(b0, tile)]
    ra, rb = _tile_ranks(wa, wb)
    o_ref[...] = (_permute_select(ra, wa, tile) + _permute_select(rb, wb, tile))[None, :]


def _merge_kv_batched_kernel(
    a_starts,
    b_starts,
    ak_ref,
    av_ref,
    bk_ref,
    bv_ref,
    ko_ref,
    vo_ref,
    *,
    tile: int,
    na: int,
    nb: int,
):
    bi = pl.program_id(0)
    ti = pl.program_id(1)
    a0 = a_starts[bi, ti]
    b0 = b_starts[bi, ti]
    wak = ak_ref[bi, pl.ds(a0, tile)]
    wbk = bk_ref[bi, pl.ds(b0, tile)]
    wav = av_ref[bi, pl.ds(a0, tile)]
    wbv = bv_ref[bi, pl.ds(b0, tile)]
    valid_a = jnp.clip(na - a0, 0, tile)
    valid_b = jnp.clip(nb - b0, 0, tile)
    ra, rb = _tile_ranks_masked(wak, wbk, valid_a, valid_b)
    ko_ref[...] = (_permute_select(ra, wak, tile) + _permute_select(rb, wbk, tile))[None, :]
    vo_ref[...] = (_permute_select(ra, wav, tile) + _permute_select(rb, wbv, tile))[None, :]


def _prepare_batched(a, b, tile):
    """Host-side partition phase for the batched kernel: one fused Alg. 2
    pass over all (row, diagonal) pairs."""
    if a.ndim != 2 or b.ndim != 2 or a.shape[0] != b.shape[0]:
        raise ValueError(f"expected (B, na) and (B, nb) with equal B, got {a.shape} and {b.shape}")
    dtype = jnp.result_type(a, b)
    a = a.astype(dtype)
    b = b.astype(dtype)
    bsz = a.shape[0]
    n = a.shape[1] + b.shape[1]
    nt = pl.cdiv(n, tile)
    diags = jnp.minimum(jnp.arange(nt, dtype=jnp.int32) * tile, n)
    a_starts = diagonal_intersections_batched(a, b, diags).astype(jnp.int32)  # (B, nt)
    b_starts = diags[None, :] - a_starts
    sent = max_sentinel(dtype)
    ap = jnp.concatenate([a, jnp.full((bsz, tile), sent, dtype)], axis=1)
    bp = jnp.concatenate([b, jnp.full((bsz, tile), sent, dtype)], axis=1)
    return ap, bp, a_starts, b_starts, bsz, n, nt, dtype


def merge_batched_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jax.Array:
    """Merge ``B`` pairs of sorted rows in one 2-D-grid SPM kernel launch.

    ``a`` is ``(B, na)``, ``b`` is ``(B, nb)``, both row-sorted; returns
    ``(B, na + nb)`` where row ``r`` is the stable A-priority merge of
    ``a[r]`` and ``b[r]`` — bit-identical to ``vmap(merge)``.
    """
    ap, bp, a_starts, b_starts, bsz, n, nt, dtype = _prepare_batched(a, b, tile)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bsz, nt),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda bi, ti, *_: (bi, ti)),
    )
    out = pl.pallas_call(
        functools.partial(_merge_batched_kernel, tile=tile),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, nt * tile), dtype),
        interpret=interpret,
    )(a_starts, b_starts, ap, bp)
    return out[:, :n]


def merge_kv_batched_pallas(
    ak: jax.Array,
    av: jax.Array,
    bk: jax.Array,
    bv: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Batched stable key-value merge on the 2-D-grid SPM kernel.

    Keys ``(B, na)``/``(B, nb)`` row-sorted; values carried along the same
    permutation.  Row ``r`` equals ``merge_kv`` of row ``r``.
    """
    if av.shape != ak.shape or bv.shape != bk.shape:
        raise ValueError(
            f"value shapes must match key shapes: keys {ak.shape}/{bk.shape}, "
            f"values {av.shape}/{bv.shape}"
        )
    akp, bkp, a_starts, b_starts, bsz, n, nt, kd = _prepare_batched(ak, bk, tile)
    vd = jnp.result_type(av, bv)
    avp = jnp.concatenate([av.astype(vd), jnp.zeros((bsz, tile), vd)], axis=1)
    bvp = jnp.concatenate([bv.astype(vd), jnp.zeros((bsz, tile), vd)], axis=1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bsz, nt),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 4,
        out_specs=[
            pl.BlockSpec((1, tile), lambda bi, ti, *_: (bi, ti)),
            pl.BlockSpec((1, tile), lambda bi, ti, *_: (bi, ti)),
        ],
    )
    ko, vo = pl.pallas_call(
        functools.partial(
            _merge_kv_batched_kernel, tile=tile, na=ak.shape[1], nb=bk.shape[1]
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nt * tile), kd),
            jax.ShapeDtypeStruct((bsz, nt * tile), vd),
        ],
        interpret=interpret,
    )(a_starts, b_starts, akp, avp, bkp, bvp)
    return ko[:, :n], vo[:, :n]


# ---------------------------------------------------------------------------
# Ragged batched merges: per-row length tables via scalar prefetch
# ---------------------------------------------------------------------------
#
# The ragged form is the batched kernel with one addition: alongside the
# (B, nt) start tables, the per-row valid lengths ride in as scalar-
# prefetch operands (SMEM).  Each (batch, tile) grid step derives its
# windows' valid prefixes from the length tables and uses the length-
# masked Merge Matrix reduction, so padding never shadows a payload and
# output slots past a row's merged length are filled with the sentinel.
# The partition phase clamps every row's diagonals to that row's total
# valid length, so short rows simply run out of work early (their
# trailing tiles write pure sentinel blocks).


def _permute_fill(rank: jax.Array, window: jax.Array, t: int) -> Tuple[jax.Array, jax.Array]:
    """Like :func:`_permute_select`, but also returns per-slot coverage."""
    k = jnp.arange(t, dtype=jnp.int32)
    onehot = rank[:, None] == k[None, :]
    zero = jnp.zeros((), window.dtype)
    val = jnp.sum(jnp.where(onehot, window[:, None], zero), axis=0)
    count = jnp.sum(onehot, axis=0, dtype=jnp.int32)
    return val, count


def _merge_batched_ragged_kernel(
    a_starts,  # scalar prefetch (SMEM): (B, nt) per-(batch, tile) A starts
    b_starts,
    a_lens,  # scalar prefetch (SMEM): (B,) per-row valid lengths
    b_lens,
    a_ref,  # (B, na + T) sentinel-masked + sentinel-padded rows
    b_ref,
    o_ref,  # (1, T) VMEM output block
    *,
    tile: int,
):
    bi = pl.program_id(0)
    ti = pl.program_id(1)
    a0 = a_starts[bi, ti]
    b0 = b_starts[bi, ti]
    wa = a_ref[bi, pl.ds(a0, tile)]
    wb = b_ref[bi, pl.ds(b0, tile)]
    valid_a = jnp.clip(a_lens[bi] - a0, 0, tile)
    valid_b = jnp.clip(b_lens[bi] - b0, 0, tile)
    ra, rb = _tile_ranks_masked(wa, wb, valid_a, valid_b)
    va, ca = _permute_fill(ra, wa, tile)
    vb, cb = _permute_fill(rb, wb, tile)
    sent = max_sentinel(wa.dtype)
    o_ref[...] = jnp.where(ca + cb > 0, va + vb, sent)[None, :]


def _merge_kv_batched_ragged_kernel(
    a_starts,
    b_starts,
    a_lens,
    b_lens,
    ak_ref,
    av_ref,
    bk_ref,
    bv_ref,
    ko_ref,
    vo_ref,
    *,
    tile: int,
):
    bi = pl.program_id(0)
    ti = pl.program_id(1)
    a0 = a_starts[bi, ti]
    b0 = b_starts[bi, ti]
    wak = ak_ref[bi, pl.ds(a0, tile)]
    wbk = bk_ref[bi, pl.ds(b0, tile)]
    wav = av_ref[bi, pl.ds(a0, tile)]
    wbv = bv_ref[bi, pl.ds(b0, tile)]
    valid_a = jnp.clip(a_lens[bi] - a0, 0, tile)
    valid_b = jnp.clip(b_lens[bi] - b0, 0, tile)
    ra, rb = _tile_ranks_masked(wak, wbk, valid_a, valid_b)
    ka, ca = _permute_fill(ra, wak, tile)
    kb, cb = _permute_fill(rb, wbk, tile)
    sent = max_sentinel(wak.dtype)
    ko_ref[...] = jnp.where(ca + cb > 0, ka + kb, sent)[None, :]
    # uncovered value slots sum to zero already — the pad-value convention
    vo_ref[...] = (_permute_select(ra, wav, tile) + _permute_select(rb, wbv, tile))[None, :]


def _prepare_batched_ragged(a, b, a_lens, b_lens, tile):
    """Partition phase for the ragged kernel: per-row clamped diagonals.

    Rows are sentinel-masked beyond their lengths (so windows stay
    sorted whatever the caller left in the padding), and each row's
    diagonals are clamped to its own total valid length — the bisection
    of ``diagonal_intersections_ragged`` then never probes padding.
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[0] != b.shape[0]:
        raise ValueError(f"expected (B, na) and (B, nb) with equal B, got {a.shape} and {b.shape}")
    dtype = jnp.result_type(a, b)
    bsz, na = a.shape
    nb = b.shape[1]
    a_lens = _as_lens(a_lens, bsz, na)
    b_lens = _as_lens(b_lens, bsz, nb)
    sent = max_sentinel(dtype)
    am = _mask_rows(a.astype(dtype), a_lens, sent)
    bm = _mask_rows(b.astype(dtype), b_lens, sent)
    n = na + nb
    nt = pl.cdiv(n, tile)
    row_total = (a_lens + b_lens)[:, None]  # (B, 1)
    diags = jnp.minimum(jnp.arange(nt, dtype=jnp.int32)[None, :] * tile, row_total)
    a_starts = diagonal_intersections_ragged(am, bm, a_lens, b_lens, diags).astype(jnp.int32)
    b_starts = diags - a_starts
    ap = jnp.concatenate([am, jnp.full((bsz, tile), sent, dtype)], axis=1)
    bp = jnp.concatenate([bm, jnp.full((bsz, tile), sent, dtype)], axis=1)
    return ap, bp, a_starts, b_starts, a_lens, b_lens, bsz, n, nt, dtype


def merge_batched_ragged_pallas(
    a: jax.Array,
    b: jax.Array,
    a_lens,
    b_lens,
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jax.Array:
    """Ragged batched merge on the 2-D ``(batch, tile)`` grid SPM kernel.

    Row ``r`` of the ``(B, na + nb)`` result starts with the stable
    A-priority merge of ``a[r, :a_lens[r]]`` and ``b[r, :b_lens[r]]``,
    followed by sentinel padding — bit-identical to
    :func:`repro.core.batched.merge_batched_ragged`.  The per-row length
    tables ride in as scalar-prefetch operands next to the start tables.
    """
    ap, bp, a_starts, b_starts, a_lens, b_lens, bsz, n, nt, dtype = _prepare_batched_ragged(
        a, b, a_lens, b_lens, tile
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(bsz, nt),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda bi, ti, *_: (bi, ti)),
    )
    out = pl.pallas_call(
        functools.partial(_merge_batched_ragged_kernel, tile=tile),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, nt * tile), dtype),
        interpret=interpret,
    )(a_starts, b_starts, a_lens, b_lens, ap, bp)
    return out[:, :n]


def merge_kv_batched_ragged_pallas(
    ak: jax.Array,
    av: jax.Array,
    bk: jax.Array,
    bv: jax.Array,
    a_lens,
    b_lens,
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Ragged batched key-value merge on the 2-D-grid SPM kernel.

    Bit-identical to :func:`repro.core.batched.merge_kv_batched_ragged`:
    merged valid pairs first, then sentinel keys with zero values.
    """
    if av.shape != ak.shape or bv.shape != bk.shape:
        raise ValueError(
            f"value shapes must match key shapes: keys {ak.shape}/{bk.shape}, "
            f"values {av.shape}/{bv.shape}"
        )
    akp, bkp, a_starts, b_starts, a_lens, b_lens, bsz, n, nt, kd = _prepare_batched_ragged(
        ak, bk, a_lens, b_lens, tile
    )
    vd = jnp.result_type(av, bv)
    avp = jnp.concatenate([av.astype(vd), jnp.zeros((bsz, tile), vd)], axis=1)
    bvp = jnp.concatenate([bv.astype(vd), jnp.zeros((bsz, tile), vd)], axis=1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(bsz, nt),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 4,
        out_specs=[
            pl.BlockSpec((1, tile), lambda bi, ti, *_: (bi, ti)),
            pl.BlockSpec((1, tile), lambda bi, ti, *_: (bi, ti)),
        ],
    )
    ko, vo = pl.pallas_call(
        functools.partial(_merge_kv_batched_ragged_kernel, tile=tile),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nt * tile), kd),
            jax.ShapeDtypeStruct((bsz, nt * tile), vd),
        ],
        interpret=interpret,
    )(a_starts, b_starts, a_lens, b_lens, akp, avp, bkp, bvp)
    return ko[:, :n], vo[:, :n]
