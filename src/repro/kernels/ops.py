"""Jitted public wrappers around the Merge Path Pallas kernels.

``merge`` / ``merge_kv`` / ``sort`` / ``sort_kv`` dispatch to the Pallas
SPM kernel when the problem is big enough to tile, and to the pure-JAX
core otherwise.  ``merge_batched`` / ``merge_kv_batched`` are the batched
(leading batch axis) forms on the 2-D ``(batch, tile)`` grid kernel; the
sorts (1-D and the new ``sort_batched`` / ``sort_kv_batched``) run their
wide rounds on the **flat round kernel** — one launch per round, with the
pow2 + sentinel padding hoisted out of the round loop (built once per
sort; see ``repro.kernels.merge_path.sort_round_pallas``).

**Tile/leaf selection**: every wrapper takes ``tile=None`` / ``leaf=None``
and resolves them through :func:`repro.kernels.tune.pick` (the
micro-bench table of the hierarchical tile engine), so consumers that
don't care get measured defaults and consumers that do (serving sampler,
MoE dispatch, distributed sort) can pass their own.

**Interpret default**: ``interpret=None`` (the default everywhere)
resolves to the module-level :data:`DEFAULT_INTERPRET`, which is ``True``
(interpret mode) unless the ``REPRO_PALLAS_INTERPRET`` environment
variable says otherwise — set ``REPRO_PALLAS_INTERPRET=0`` on a real TPU
and every call site in the repo compiles, no call-site edits needed.

**Gradients**: the sorts and top-ks here are *permutations* of their
inputs, and Siebert & Träff's stable co-rank partition guarantees the
permutation is well-defined even under duplicate keys — so every wrapper
defines a ``jax.custom_vjp`` whose forward saves the gather indices (the
stable argsort, computed by the same kernel with an iota payload) and
whose backward is ONE inverse-gather scatter of the cotangents.  That
makes the backward exact in any dtype (each output cotangent lands on
exactly one input slot, no floating-point accumulation), bit-identical
to ``jax.grad`` of the pure-JAX core route, and shields the Pallas
internals from tracing AD.  Ragged / sentinel-masked top-k slots
(``index == -1``) contribute exactly zero.  Integer inputs take the
plain kernel path (no tangents exist for them).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import dtypes as _jdtypes

from repro.analysis.registry import kernel_contract
from repro.core import batched as _bat
from repro.core import merge_path as _mp
from . import merge_path as _kern
from . import tune as _tune

# single source of truth for the env-overridable interpret default — the
# kernel wrappers, tune.autotune, and the benchmarks all resolve through
# it (re-exported here because ops is the public dispatch surface)
DEFAULT_INTERPRET: bool = _kern.DEFAULT_INTERPRET
_interp = _kern._interp


def _resolve(n: int, dtype, tile: Optional[int], leaf: Optional[int]) -> Tuple[int, int]:
    """Fill unspecified tile/leaf from the autotune table."""
    t, s = _tune.pick(n, dtype)
    tile = t if tile is None else tile
    leaf = s if leaf is None else leaf
    return tile, max(1, min(leaf, tile))


def _sort_tile(n: int, dtype, tile: Optional[int], leaf: Optional[int]) -> Tuple[int, int]:
    """Tile/leaf resolution for the sorts: the flat rounds need
    ``tile | 2 * width`` with pow2 widths, so an explicitly passed tile
    must be a power of two — reject it loudly rather than silently
    running a different tile than the caller measured.  (The autotune
    table only ever emits powers of two.)"""
    tile, leaf = _resolve(n, dtype, tile, leaf)
    if tile & (tile - 1):
        raise ValueError(
            f"sort tile must be a power of two (flat sort rounds require "
            f"tile | 2 * width), got {tile}"
        )
    return tile, leaf


_JIT = functools.partial(
    jax.jit, static_argnames=("tile", "leaf", "engine", "interpret")
)


@kernel_contract(
    kind="merge",
    tie_safe="keys-only: a window pad tied with a real sentinel-valued key "
             "is bit-identical to it, so any rank assignment among the tie "
             "yields the same output sequence",
)
@_JIT
def merge(
    a: jax.Array,
    b: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Stable merge of two sorted 1-D arrays (Pallas SPM kernel)."""
    n = a.shape[0] + b.shape[0]
    tile, leaf = _resolve(n, jnp.result_type(a, b), tile, leaf)
    if n <= tile:
        return _mp.merge(a, b)
    return _kern.merge_pallas(
        a, b, tile=tile, leaf=leaf, engine=engine, interpret=_interp(interpret)
    )


@kernel_contract(kind="merge", carries_values=True, masked_ranks=True)
@_JIT
def merge_kv(
    ak: jax.Array,
    av: jax.Array,
    bk: jax.Array,
    bv: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Stable key-value merge (Pallas SPM kernel)."""
    n = ak.shape[0] + bk.shape[0]
    tile, leaf = _resolve(n, jnp.result_type(ak, bk), tile, leaf)
    if n <= tile:
        return _mp.merge_kv(ak, av, bk, bv)
    return _kern.merge_kv_pallas(
        ak, av, bk, bv, tile=tile, leaf=leaf, engine=engine, interpret=_interp(interpret)
    )


@kernel_contract(
    kind="merge",
    batched=True,
    tie_safe="keys-only: sentinel-tied pads are value-identical to the real "
             "key, so the merged row is unchanged whichever wins the tie",
)
@_JIT
def merge_batched(
    a: jax.Array,
    b: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Stable merge of ``B`` row pairs: ``(B, na) + (B, nb) -> (B, na+nb)``.

    One 2-D-grid kernel launch for the whole batch when rows are wide
    enough to tile; the fused pure-JAX batched merge otherwise.
    """
    n = a.shape[1] + b.shape[1]
    tile, leaf = _resolve(n, jnp.result_type(a, b), tile, leaf)
    if n <= tile:
        return _bat.merge_batched(a, b)
    return _kern.merge_batched_pallas(
        a, b, tile=tile, leaf=leaf, engine=engine, interpret=_interp(interpret)
    )


@kernel_contract(kind="merge", batched=True, carries_values=True, masked_ranks=True)
@_JIT
def merge_kv_batched(
    ak: jax.Array,
    av: jax.Array,
    bk: jax.Array,
    bv: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Stable batched key-value merge (2-D-grid Pallas kernel when wide)."""
    n = ak.shape[1] + bk.shape[1]
    tile, leaf = _resolve(n, jnp.result_type(ak, bk), tile, leaf)
    if n <= tile:
        return _bat.merge_kv_batched(ak, av, bk, bv)
    return _kern.merge_kv_batched_pallas(
        ak, av, bk, bv, tile=tile, leaf=leaf, engine=engine, interpret=_interp(interpret)
    )


@kernel_contract(kind="merge", batched=True, ragged=True, masked_ranks=True)
@_JIT
def merge_batched_ragged(
    a: jax.Array,
    b: jax.Array,
    a_lens: jax.Array,
    b_lens: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Ragged batched merge: per-row valid lengths, sentinel-padded tails.

    Dispatches like :func:`merge_batched`: the fused pure-JAX ragged merge
    for narrow rows, the 2-D-grid ragged kernel (lengths via scalar
    prefetch) when rows are wide enough to tile.
    """
    n = a.shape[1] + b.shape[1]
    tile, leaf = _resolve(n, jnp.result_type(a, b), tile, leaf)
    if n <= tile:
        return _bat.merge_batched_ragged(a, b, a_lens, b_lens)
    return _kern.merge_batched_ragged_pallas(
        a, b, a_lens, b_lens, tile=tile, leaf=leaf, engine=engine,
        interpret=_interp(interpret),
    )


@kernel_contract(
    kind="merge", batched=True, ragged=True, carries_values=True, masked_ranks=True
)
@_JIT
def merge_kv_batched_ragged(
    ak: jax.Array,
    av: jax.Array,
    bk: jax.Array,
    bv: jax.Array,
    a_lens: jax.Array,
    b_lens: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Ragged batched key-value merge (2-D-grid ragged kernel when wide)."""
    n = ak.shape[1] + bk.shape[1]
    tile, leaf = _resolve(n, jnp.result_type(ak, bk), tile, leaf)
    if n <= tile:
        return _bat.merge_kv_batched_ragged(ak, av, bk, bv, a_lens, b_lens)
    return _kern.merge_kv_batched_ragged_pallas(
        ak, av, bk, bv, a_lens, b_lens, tile=tile, leaf=leaf, engine=engine,
        interpret=_interp(interpret),
    )


# ---------------------------------------------------------------------------
# Sorts: flat rounds, padding hoisted out of the loop
# ---------------------------------------------------------------------------


def _sort_rounds(flat: jax.Array, m: int, tile: int, leaf: int, engine: str, interpret: bool) -> jax.Array:
    """Bottom-up merge-sort rounds over a flat ``(B * m,)`` buffer of
    width-1 runs (``m`` = per-row pow2 width; pairs never straddle a row
    because ``m`` is a multiple of every round width).

    Narrow rounds (``2 * width <= tile``) are fused pure-JAX batched
    merges on reshaped views; wide rounds are flat-kernel launches
    sharing ONE sentinel tail appended here, once — the padding hoist
    that used to happen per round inside ``_prepare_batched``.
    """
    width = 1
    while width < m and 2 * width <= tile:
        runs = flat.reshape(-1, 2, width)
        flat = _bat.merge_batched(runs[:, 0], runs[:, 1]).reshape(-1)
        width *= 2
    if width < m:
        total = flat.shape[0]
        xf = jnp.concatenate(
            [flat, jnp.full((tile,), _mp.max_sentinel(flat.dtype), flat.dtype)]
        )
        while width < m:
            xf = _kern.sort_round_pallas(
                xf, width, tile=tile, leaf=leaf, engine=engine, interpret=interpret
            )
            width *= 2
        flat = xf[:total]
    return flat


def _sort_rounds_kv(
    kflat: jax.Array, vflat: jax.Array, m: int, tile: int, leaf: int, engine: str, interpret: bool
) -> Tuple[jax.Array, jax.Array]:
    """Key-value :func:`_sort_rounds` (values' hoisted tail is zeros)."""
    width = 1
    while width < m and 2 * width <= tile:
        kr = kflat.reshape(-1, 2, width)
        vr = vflat.reshape(-1, 2, width)
        kflat, vflat = _bat.merge_kv_batched(kr[:, 0], vr[:, 0], kr[:, 1], vr[:, 1])
        kflat, vflat = kflat.reshape(-1), vflat.reshape(-1)
        width *= 2
    if width < m:
        total = kflat.shape[0]
        kf = jnp.concatenate(
            [kflat, jnp.full((tile,), _mp.max_sentinel(kflat.dtype), kflat.dtype)]
        )
        vf = jnp.concatenate([vflat, jnp.zeros((tile,), vflat.dtype)])
        while width < m:
            kf, vf = _kern.sort_round_kv_pallas(
                kf, vf, width, tile=tile, leaf=leaf, engine=engine, interpret=interpret
            )
            width *= 2
        kflat, vflat = kf[:total], vf[:total]
    return kflat, vflat


# --- raw (non-differentiable) sort bodies -----------------------------------


def _sort_impl(x, n, tile, leaf, engine, interp):
    xp = _mp._pad_pow2(x, _mp.max_sentinel(x.dtype))
    return _sort_rounds(xp, xp.shape[0], tile, leaf, engine, interp)[:n]


def _sort_kv_impl(keys, values, n, tile, leaf, engine, interp):
    kp = _mp._pad_pow2(keys, _mp.max_sentinel(keys.dtype))
    vp = _mp._pad_pow2(values, jnp.zeros((), values.dtype))
    ks, vs = _sort_rounds_kv(kp, vp, kp.shape[0], tile, leaf, engine, interp)
    return ks[:n], vs[:n]


def _sort_batched_impl(x, n, tile, leaf, engine, interp):
    bsz = x.shape[0]
    xp = _bat._pad_rows_pow2(x, _mp.max_sentinel(x.dtype))
    m = xp.shape[1]
    out = _sort_rounds(xp.reshape(-1), m, tile, leaf, engine, interp)
    return out.reshape(bsz, m)[:, :n]


def _sort_kv_batched_impl(keys, values, n, tile, leaf, engine, interp):
    bsz = keys.shape[0]
    kp = _bat._pad_rows_pow2(keys, _mp.max_sentinel(keys.dtype))
    vp = _bat._pad_rows_pow2(values, jnp.zeros((), values.dtype))
    m = kp.shape[1]
    ks, vs = _sort_rounds_kv(
        kp.reshape(-1), vp.reshape(-1), m, tile, leaf, engine, interp
    )
    return ks.reshape(bsz, m)[:, :n], vs.reshape(bsz, m)[:, :n]


# --- permutation-transpose VJP glue -----------------------------------------


def _inexact(dtype) -> bool:
    return jnp.issubdtype(jnp.dtype(dtype), jnp.inexact)


def _float0(shape):
    """float0 cotangent zeros — what custom_vjp requires for int primals."""
    return np.zeros(shape, _jdtypes.float0)


def _iota_like(x) -> jax.Array:
    """Row-index payload whose sorted order IS the stable argsort."""
    if x.ndim == 1:
        return jnp.arange(x.shape[0], dtype=jnp.int32)
    return jnp.broadcast_to(
        jnp.arange(x.shape[-1], dtype=jnp.int32)[None, :], x.shape
    )


def _scatter_inverse(perm: jax.Array, ct: jax.Array) -> jax.Array:
    """Permutation transpose: route output cotangents back to input slots.

    ``perm`` is a (batched) permutation — each source index appears
    exactly once — so the scatter is an exact inverse gather in any
    dtype (no accumulation happens).
    """
    if perm.ndim == 1:
        return jnp.zeros(perm.shape, ct.dtype).at[perm].set(ct)
    rows = jnp.arange(perm.shape[0], dtype=jnp.int32)[:, None]
    return jnp.zeros(perm.shape, ct.dtype).at[rows, perm].set(ct)


@kernel_contract(kind="sort", masked_ranks=True, pow2_tile=True, differentiable=True)
@_JIT
def sort(
    x: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Bottom-up merge sort whose wide rounds run on the flat round kernel.

    Every round is ONE call: narrow rounds (2*width <= tile) use the fused
    pure-JAX batched merge, wide rounds the flat ``(pair, tile)`` kernel —
    no Python-level loop over run pairs, and the pow2 + sentinel padding
    is built once per sort, not re-appended every round.

    Differentiable: under AD the forward runs the kv kernel with an iota
    payload to capture the stable argsort, and the backward is one
    inverse-gather scatter — the exact permutation transpose.
    """
    n = x.shape[0]
    if n <= 1:
        return x
    tile, leaf = _sort_tile(n, x.dtype, tile, leaf)
    interp = _interp(interpret)
    if not _inexact(x.dtype):
        return _sort_impl(x, n, tile, leaf, engine, interp)

    @jax.custom_vjp
    def f(xx):
        return _sort_impl(xx, n, tile, leaf, engine, interp)

    def fwd(xx):
        ks, perm = _sort_kv_impl(xx, _iota_like(xx), n, tile, leaf, engine, interp)
        return ks, perm

    def bwd(perm, dy):
        return (_scatter_inverse(perm, dy),)

    f.defvjp(fwd, bwd)
    return f(x)


@kernel_contract(
    kind="sort", carries_values=True, masked_ranks=True, pow2_tile=True,
    differentiable=True,
)
@_JIT
def sort_kv(
    keys: jax.Array,
    values: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Stable key-value merge sort; wide rounds on the flat round kernel.

    Differentiable in both keys and values via the permutation-transpose
    VJP (int operands get float0 cotangents, per custom_vjp convention).
    """
    n = keys.shape[0]
    if n <= 1:
        return keys, values
    tile, leaf = _sort_tile(n, keys.dtype, tile, leaf)
    interp = _interp(interpret)
    kx, vx = _inexact(keys.dtype), _inexact(values.dtype)
    if not (kx or vx):
        return _sort_kv_impl(keys, values, n, tile, leaf, engine, interp)

    @jax.custom_vjp
    def f(k, v):
        return _sort_kv_impl(k, v, n, tile, leaf, engine, interp)

    def fwd(k, v):
        ks, perm = _sort_kv_impl(k, _iota_like(k), n, tile, leaf, engine, interp)
        # stability makes v[perm] bit-identical to the kernel's value output
        return (ks, jnp.take(v, perm)), perm

    def bwd(perm, cts):
        dks, dvs = cts
        dk = _scatter_inverse(perm, dks) if kx else _float0((n,))
        dv = _scatter_inverse(perm, dvs) if vx else _float0((n,))
        return dk, dv

    f.defvjp(fwd, bwd)
    return f(keys, values)


@kernel_contract(
    kind="sort", batched=True, masked_ranks=True, pow2_tile=True,
    differentiable=True,
)
@_JIT
def sort_batched(
    x: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Sort every row of ``(B, n)`` ascending; rows ride the same flat
    rounds as :func:`sort` (the batch axis is folded into the run-pair
    axis, so per-round launch count is independent of ``B``).
    Differentiable via the per-row permutation-transpose VJP."""
    bsz, n = x.shape
    if n <= 1:
        return x
    tile, leaf = _sort_tile(n, x.dtype, tile, leaf)
    interp = _interp(interpret)
    if not _inexact(x.dtype):
        return _sort_batched_impl(x, n, tile, leaf, engine, interp)

    @jax.custom_vjp
    def f(xx):
        return _sort_batched_impl(xx, n, tile, leaf, engine, interp)

    def fwd(xx):
        ks, perm = _sort_kv_batched_impl(
            xx, _iota_like(xx), n, tile, leaf, engine, interp
        )
        return ks, perm

    def bwd(perm, dy):
        return (_scatter_inverse(perm, dy),)

    f.defvjp(fwd, bwd)
    return f(x)


@kernel_contract(
    kind="sort", batched=True, carries_values=True, masked_ranks=True,
    pow2_tile=True, differentiable=True,
)
@_JIT
def sort_kv_batched(
    keys: jax.Array,
    values: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Row-wise stable key-value sort of ``(B, n)`` keys (ascending),
    kernel-backed like :func:`sort_batched` and differentiable in both
    operands via the per-row permutation-transpose VJP."""
    bsz, n = keys.shape
    if n <= 1:
        return keys, values
    tile, leaf = _sort_tile(n, keys.dtype, tile, leaf)
    interp = _interp(interpret)
    kx, vx = _inexact(keys.dtype), _inexact(values.dtype)
    if not (kx or vx):
        return _sort_kv_batched_impl(keys, values, n, tile, leaf, engine, interp)

    @jax.custom_vjp
    def f(k, v):
        return _sort_kv_batched_impl(k, v, n, tile, leaf, engine, interp)

    def fwd(k, v):
        ks, perm = _sort_kv_batched_impl(
            k, _iota_like(k), n, tile, leaf, engine, interp
        )
        rows = jnp.arange(bsz, dtype=jnp.int32)[:, None]
        return (ks, v[rows, perm]), perm

    def bwd(perm, cts):
        dks, dvs = cts
        dk = _scatter_inverse(perm, dks) if kx else _float0((bsz, n))
        dv = _scatter_inverse(perm, dvs) if vx else _float0((bsz, n))
        return dk, dv

    f.defvjp(fwd, bwd)
    return f(keys, values)


@kernel_contract(kind="merge_k", ragged=True, masked_ranks=True)
def merge_k(
    runs: jax.Array,
    lens: Optional[jax.Array] = None,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """k-way tournament merge whose rounds run on the ragged batched kernel.

    Same contract as :func:`repro.core.batched.merge_k` restricted to a
    stacked ``(k, n)`` runs array (stable with lower-run priority; ``lens``
    optionally gives per-run valid lengths; output is always the
    ``(k * n,)`` merged valid prefix followed by sentinel padding — a
    traced ``lens`` forbids trimming further).  Each of the
    ``ceil(log2 k)`` tournament rounds is one :func:`merge_batched_ragged`
    call, i.e. the hierarchical tile engine once the runs are wide enough
    to tile — this is ``distributed_sort``'s bucket combine for
    ``local_sort="pallas", combine="tournament"``.
    """
    runs = jnp.asarray(runs)
    if runs.ndim != 2:
        raise ValueError(f"expected (k, n) runs, got shape {runs.shape}")
    k, n = runs.shape
    sent = _mp.max_sentinel(runs.dtype)
    run_lens = (
        jnp.full((k,), n, jnp.int32) if lens is None else _bat._as_lens(lens, k, n)
    )
    stacked = _bat._mask_rows(runs, run_lens, sent)
    target = 1 << max(0, (k - 1).bit_length())
    if target != k:
        pad = jnp.full((target - k, n), sent, stacked.dtype)
        stacked = jnp.concatenate([stacked, pad], axis=0)
        run_lens = jnp.concatenate([run_lens, jnp.zeros((target - k,), jnp.int32)])
    while stacked.shape[0] > 1:
        stacked = merge_batched_ragged(
            stacked[0::2],
            stacked[1::2],
            run_lens[0::2],
            run_lens[1::2],
            tile=tile,
            leaf=leaf,
            engine=engine,
            interpret=interpret,
        )
        run_lens = run_lens[0::2] + run_lens[1::2]
    # pow2 pad rows only ever append sentinels, so the (k * n,) prefix
    # holds every valid element — same output width as the core forms
    return stacked[0][: k * n]


@kernel_contract(
    kind="topk", batched=True, carries_values=True, masked_ranks=True,
    pow2_tile=True, differentiable=True,
)
@functools.partial(
    jax.jit, static_argnames=("k", "tile", "leaf", "engine", "interpret")
)
def topk_batched(
    x: jax.Array,
    k: int,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Row-wise descending top-k on the kernel-backed batched kv-sort.

    Same contract as :func:`repro.core.batched.topk_batched` (stable,
    ``lax.top_k`` tie-breaking, exact at ``iinfo.min`` via
    ``flip_desc``), but the sort rounds run on the flat round kernel
    with tuned ``(tile, leaf)`` — the serving sampler's wide-vocab path.
    Differentiable: the backward scatters the k value-cotangents back to
    their source columns (one exact inverse gather).
    """
    bsz, n = x.shape
    k = min(k, n)
    tile, leaf = _sort_tile(n, x.dtype, tile, leaf)
    interp = _interp(interpret)

    def _primal(xx):
        _, perm = _sort_kv_batched_impl(
            _mp.flip_desc(xx), _iota_like(xx), n, tile, leaf, engine, interp
        )
        top_idx = perm[:, :k]
        return jnp.take_along_axis(xx, top_idx, axis=1), top_idx

    if not _inexact(x.dtype):
        return _primal(x)

    @jax.custom_vjp
    def f(xx):
        return _primal(xx)

    def fwd(xx):
        vals, top_idx = _primal(xx)
        return (vals, top_idx), top_idx

    def bwd(top_idx, cts):
        dvals, _ = cts  # index cotangent is float0
        rows = jnp.arange(bsz, dtype=jnp.int32)[:, None]
        return (jnp.zeros((bsz, n), dvals.dtype).at[rows, top_idx].set(dvals),)

    f.defvjp(fwd, bwd)
    return f(x)


@kernel_contract(
    kind="topk", batched=True, ragged=True, carries_values=True,
    masked_ranks=True, pow2_tile=True, differentiable=True,
)
@functools.partial(
    jax.jit, static_argnames=("k", "tile", "leaf", "engine", "interpret")
)
def topk_batched_ragged(
    x: jax.Array,
    k: int,
    lens: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Ragged row-wise descending top-k, kernel-backed.

    Contract matches :func:`repro.core.batched.topk_batched_ragged`
    exactly (masked slots: index ``-1``, dtype-min value); the underlying
    sort is the same sentinel-mask-then-sort reduction the core ragged
    kv-sort uses, so padded rows are bit-identical to their truncations.
    Differentiable: cotangents of masked (sentinel) slots are provably
    zeroed — only valid slots scatter back, so rows shorter than ``k``
    get exactly the gradient their truncation would.
    """
    bsz, n = x.shape
    k = min(k, n)
    lens = _bat._as_lens(lens, bsz, n)
    tile, leaf = _sort_tile(n, x.dtype, tile, leaf)
    interp = _interp(interpret)

    def _primal(xx, ln):
        keys = _bat._mask_rows(_mp.flip_desc(xx), ln, _mp.max_sentinel(xx.dtype))
        _, perm = _sort_kv_batched_impl(
            keys, _iota_like(xx), n, tile, leaf, engine, interp
        )
        top_idx = perm[:, :k]
        vals = jnp.take_along_axis(xx, top_idx, axis=1)
        slot_valid = jnp.arange(k, dtype=jnp.int32)[None, :] < ln[:, None]
        vals = jnp.where(slot_valid, vals, _mp.min_sentinel(xx.dtype))
        top_idx = jnp.where(slot_valid, top_idx, -1)
        return vals, top_idx

    if not _inexact(x.dtype):
        return _primal(x, lens)

    @jax.custom_vjp
    def f(xx, ln):
        return _primal(xx, ln)

    def fwd(xx, ln):
        vals, top_idx = _primal(xx, ln)
        return (vals, top_idx), top_idx

    def bwd(top_idx, cts):
        dvals, _ = cts
        valid = top_idx >= 0
        safe_idx = jnp.where(valid, top_idx, 0)
        contrib = jnp.where(valid, dvals, jnp.zeros((), dvals.dtype))
        rows = jnp.arange(bsz, dtype=jnp.int32)[:, None]
        # .add (not .set): masked slots alias column 0 with zero contribution
        dx = jnp.zeros((bsz, n), dvals.dtype).at[rows, safe_idx].add(contrib)
        return dx, _float0((bsz,))

    f.defvjp(fwd, bwd)
    return f(x, lens)
