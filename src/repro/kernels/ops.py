"""Guarded public wrappers around the Merge Path Pallas kernels.

``merge`` / ``merge_kv`` / ``sort`` / ``sort_kv`` dispatch to the Pallas
SPM kernel when the problem is big enough to tile, and to the pure-JAX
core otherwise.  ``merge_batched`` / ``merge_kv_batched`` are the batched
(leading batch axis) forms on the 2-D ``(batch, tile)`` grid kernel; the
sorts (1-D and ``sort_batched`` / ``sort_kv_batched``) run their wide
rounds on the **flat round kernel** — one launch per round, with the
pow2 + sentinel padding hoisted out of the round loop (built once per
sort; see ``repro.kernels.merge_path.sort_round_pallas``).

**Guarded dispatch**: every public entry point routes through
:func:`repro.runtime.resilience.guarded_call`.  On an eager call (no JAX
tracers among the operands) the wrapper walks the fallback chain
``pallas-<engine> -> pallas-matrix -> core [-> core-resort]``: preflight
validates the call against the ``@kernel_contract`` registry (tile
legality, the A005 VMEM model, length bounds), launch failures are caught
and degrade to the next edge, and — when verification is active (a fault
plan is injected, or ``REPRO_GUARD_VERIFY=1``) — each attempt's output is
checked for total-order sortedness before it is accepted.  The terminal
``core-resort`` edge of the merges *re-sorts* the concatenated inputs
(stable sort == stable A-priority merge), which repairs even a violated
sorted-input precondition, e.g. NaN-laced keys.  Under tracing
(``jit`` / ``grad`` / ``vmap`` / ``eval_shape``) the wrapper dispatches
the primary attempt directly — Python cannot branch on device failures
inside a trace.  See ``docs/robustness.md``.

**Tile/leaf selection**: every wrapper takes ``tile=None`` / ``leaf=None``
and resolves them through :func:`repro.kernels.tune.pick` (the
micro-bench table of the hierarchical tile engine), so consumers that
don't care get measured defaults and consumers that do (serving sampler,
MoE dispatch, distributed sort) can pass their own.

**Interpret default**: ``interpret=None`` (the default everywhere)
resolves to the module-level :data:`DEFAULT_INTERPRET`, which is ``True``
(interpret mode) unless the ``REPRO_PALLAS_INTERPRET`` environment
variable says otherwise — set ``REPRO_PALLAS_INTERPRET=0`` on a real TPU
and every call site in the repo compiles, no call-site edits needed.

**NaN keys**: the float sort / top-k paths compare
:func:`repro.core.merge_path.total_order_keys` of the keys (same-width
int keys, NaN last) instead of the raw floats, so NaN keys order
deterministically and identically on every engine.  For NaN-free input
the int key order coincides with the float order — results are
bit-identical to the previous raw-float comparisons.

**Gradients**: the sorts and top-ks here are *permutations* of their
inputs, and Siebert & Träff's stable co-rank partition guarantees the
permutation is well-defined even under duplicate keys — so every sort
defines a ``jax.custom_vjp`` whose forward saves the gather indices (the
stable argsort, computed by the same kernel with an iota payload) and
whose backward is ONE inverse-gather scatter of the cotangents.  That
makes the backward exact in any dtype (each output cotangent lands on
exactly one input slot, no floating-point accumulation), bit-identical
to ``jax.grad`` of the pure-JAX core route, and shields the Pallas
internals from tracing AD.  Ragged / sentinel-masked top-k slots
(``index == -1``) contribute exactly zero.  Integer inputs take the
plain kernel path (no tangents exist for them).
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import dtypes as _jdtypes

from repro.analysis.registry import kernel_contract
from repro.core import batched as _bat
from repro.core import merge_path as _mp
from repro.runtime import faults as _faults
from repro.runtime import resilience as _res
from . import merge_path as _kern
from . import tune as _tune

# single source of truth for the env-overridable interpret default — the
# kernel wrappers, tune.autotune, and the benchmarks all resolve through
# it (re-exported here because ops is the public dispatch surface)
DEFAULT_INTERPRET: bool = _kern.DEFAULT_INTERPRET
_interp = _kern._interp


def _resolve(n: int, dtype, tile: Optional[int], leaf: Optional[int]) -> Tuple[int, int]:
    """Fill unspecified tile/leaf from the autotune table."""
    t, s = _tune.pick(n, dtype)
    tile = t if tile is None else tile
    leaf = s if leaf is None else leaf
    return tile, max(1, min(leaf, tile))


def _sort_tile(n: int, dtype, tile: Optional[int], leaf: Optional[int]) -> Tuple[int, int]:
    """Tile/leaf resolution for the sorts: the flat rounds need
    ``tile | 2 * width`` with pow2 widths, so an explicitly passed tile
    must be a power of two — reject it loudly rather than silently
    running a different tile than the caller measured.  (The autotune
    table only ever emits powers of two.)"""
    tile, leaf = _resolve(n, dtype, tile, leaf)
    if tile & (tile - 1):
        raise ValueError(
            f"sort tile must be a power of two (flat sort rounds require "
            f"tile | 2 * width), got {tile}"
        )
    return tile, leaf


_JIT = functools.partial(
    jax.jit, static_argnames=("tile", "leaf", "engine", "interpret")
)
_JITK = functools.partial(
    jax.jit, static_argnames=("k", "tile", "leaf", "engine", "interpret")
)


# ---------------------------------------------------------------------------
# guarded dispatch plumbing
# ---------------------------------------------------------------------------


def _meta(n, dtype, tile=None, leaf=None, batch=1, ragged=False) -> dict:
    """Concrete call geometry for preflight (see resilience.preflight)."""
    return {
        "n": int(n),
        "batch": int(batch),
        "dtype": str(jnp.dtype(dtype)),
        "tile": None if tile is None else int(tile),
        "leaf": None if leaf is None else int(leaf),
        "ragged": bool(ragged),
    }


def _guard(
    op: str,
    args: tuple,
    *,
    engine: str,
    interpret: Optional[bool],
    launch: Callable,
    core: Callable,
    resort: Optional[Callable] = None,
    keys: Sequence[int] = (),
    meta: Optional[dict] = None,
    verifier: Optional[Callable] = None,
):
    """Route one public-op call through the guarded dispatch chain.

    ``launch(args, engine, interp)`` runs the jitted kernel body;
    ``core`` is the pure-JAX twin and ``resort`` (merges only) the
    precondition-repairing re-sort of the concatenated inputs.  ``keys``
    lists the positions of key operands in ``args`` for NaN lacing.
    Bypasses (primary attempt only) under tracing or ``REPRO_GUARD=0``.
    """
    interp = _interp(interpret)
    if not _res.guard_enabled() or _res.is_tracing(*args):
        return launch(args, engine, interp)
    idx = _faults.next_index(op)
    args = _faults.maybe_nan_lace(op, idx, args, keys)
    attempts = [(f"pallas-{engine}", lambda: launch(args, engine, interp))]
    if engine != "matrix":
        attempts.append(("pallas-matrix", lambda: launch(args, "matrix", interp)))
    attempts.append(("core", lambda: core(*args)))
    if resort is not None:
        attempts.append(("core-resort", lambda: resort(*args)))
    return _res.guarded_call(op, attempts, index=idx, meta=meta, verifier=verifier)


# core twins, jitted once at module level (the chain's oracle edges)
_core_merge = jax.jit(_mp.merge)
_core_merge_kv = jax.jit(_mp.merge_kv)
_core_merge_batched = jax.jit(_bat.merge_batched)
_core_merge_kv_batched = jax.jit(_bat.merge_kv_batched)
_core_merge_batched_ragged = jax.jit(_bat.merge_batched_ragged)
_core_merge_kv_batched_ragged = jax.jit(_bat.merge_kv_batched_ragged)
_core_sort = jax.jit(_mp.merge_sort)
_core_sort_kv = jax.jit(_mp.merge_sort_kv)
_core_sort_batched = jax.jit(_bat.merge_sort_batched)
_core_sort_kv_batched = jax.jit(_bat.merge_sort_kv_batched)
_core_topk_batched = jax.jit(_bat.topk_batched, static_argnums=(1,))
_core_topk_batched_ragged = jax.jit(_bat.topk_batched_ragged, static_argnums=(1,))
_core_merge_k = jax.jit(_bat.merge_k)


# re-sort fallbacks: a stable sort of the row-concatenation [a; b] IS the
# stable A-priority merge (position order gives A priority), and — unlike
# every merge route — needs no sorted-input precondition, so it even
# repairs NaN-laced keys (total-order: NaN sorts last, deterministically).


@jax.jit
def _resort_merge(a, b):
    dt = jnp.result_type(a, b)
    cat = jnp.concatenate([a.astype(dt), b.astype(dt)])
    _, out = _mp.merge_sort_kv(_mp.total_order_keys(cat), cat)
    return out


@jax.jit
def _resort_merge_kv(ak, av, bk, bv):
    kd = jnp.result_type(ak, bk)
    vd = jnp.result_type(av, bv)
    k = jnp.concatenate([ak.astype(kd), bk.astype(kd)])
    v = jnp.concatenate([av.astype(vd), bv.astype(vd)])
    _, perm = _mp.merge_sort_kv(
        _mp.total_order_keys(k), jnp.arange(k.shape[0], dtype=jnp.int32)
    )
    return jnp.take(k, perm), jnp.take(v, perm)


@jax.jit
def _resort_merge_batched(a, b):
    dt = jnp.result_type(a, b)
    cat = jnp.concatenate([a.astype(dt), b.astype(dt)], axis=1)
    _, out = _bat.merge_sort_kv_batched(_mp.total_order_keys(cat), cat)
    return out


@jax.jit
def _resort_merge_kv_batched(ak, av, bk, bv):
    kd = jnp.result_type(ak, bk)
    vd = jnp.result_type(av, bv)
    k = jnp.concatenate([ak.astype(kd), bk.astype(kd)], axis=1)
    v = jnp.concatenate([av.astype(vd), bv.astype(vd)], axis=1)
    _, perm = _bat.merge_sort_kv_batched(_mp.total_order_keys(k), _iota_like(k))
    rows = jnp.arange(k.shape[0], dtype=jnp.int32)[:, None]
    return k[rows, perm], v[rows, perm]


def _ragged_valid(bsz: int, na: int, nb: int, a_lens, b_lens):
    """(valid mask over the concat row, merged lengths) for ragged resorts."""
    col = jnp.arange(na + nb, dtype=jnp.int32)[None, :]
    valid = jnp.where(col < na, col < a_lens[:, None], (col - na) < b_lens[:, None])
    return col, valid, a_lens + b_lens


@jax.jit
def _resort_merge_batched_ragged(a, b, a_lens, b_lens):
    dt = jnp.result_type(a, b)
    bsz, na = a.shape
    nb = b.shape[1]
    a_lens = _bat._as_lens(a_lens, bsz, na)
    b_lens = _bat._as_lens(b_lens, bsz, nb)
    cat = jnp.concatenate([a.astype(dt), b.astype(dt)], axis=1)
    col, valid, merged = _ragged_valid(bsz, na, nb, a_lens, b_lens)
    # mask pads in int total-order key space: the int sentinel is strictly
    # above every real key (incl. NaN / +inf), so pads can never interleave
    tok = _mp.total_order_keys(cat)
    tok = jnp.where(valid, tok, _mp.max_sentinel(tok.dtype))
    _, perm = _bat.merge_sort_kv_batched(tok, _iota_like(cat))
    rows = jnp.arange(bsz, dtype=jnp.int32)[:, None]
    out = cat[rows, perm]
    return jnp.where(col < merged[:, None], out, _mp.max_sentinel(dt))


@jax.jit
def _resort_merge_kv_batched_ragged(ak, av, bk, bv, a_lens, b_lens):
    kd = jnp.result_type(ak, bk)
    vd = jnp.result_type(av, bv)
    bsz, na = ak.shape
    nb = bk.shape[1]
    a_lens = _bat._as_lens(a_lens, bsz, na)
    b_lens = _bat._as_lens(b_lens, bsz, nb)
    k = jnp.concatenate([ak.astype(kd), bk.astype(kd)], axis=1)
    v = jnp.concatenate([av.astype(vd), bv.astype(vd)], axis=1)
    col, valid, merged = _ragged_valid(bsz, na, nb, a_lens, b_lens)
    tok = _mp.total_order_keys(k)
    tok = jnp.where(valid, tok, _mp.max_sentinel(tok.dtype))
    _, perm = _bat.merge_sort_kv_batched(tok, _iota_like(k))
    rows = jnp.arange(bsz, dtype=jnp.int32)[:, None]
    in_row = col < merged[:, None]
    ks = jnp.where(in_row, k[rows, perm], _mp.max_sentinel(kd))
    vs = jnp.where(in_row, v[rows, perm], jnp.zeros((), vd))
    return ks, vs


def _ragged_lens_np(a_lens, b_lens, bsz: int, na: int, nb: int) -> np.ndarray:
    """Host merged lengths for the ragged verifiers (guard-active path only)."""
    la = np.clip(np.asarray(a_lens, dtype=np.int64).reshape(-1), 0, na)
    lb = np.clip(np.asarray(b_lens, dtype=np.int64).reshape(-1), 0, nb)
    return la + lb


# ---------------------------------------------------------------------------
# merges
# ---------------------------------------------------------------------------


@_JIT
def _merge_launch(a, b, *, tile, leaf, engine, interpret):
    n = a.shape[0] + b.shape[0]
    if n <= tile:
        return _mp.merge(a, b)
    return _kern.merge_pallas(
        a, b, tile=tile, leaf=leaf, engine=engine, interpret=interpret
    )


@kernel_contract(
    kind="merge",
    tie_safe="keys-only: a window pad tied with a real sentinel-valued key "
             "is bit-identical to it, so any rank assignment among the tie "
             "yields the same output sequence",
)
def merge(
    a: jax.Array,
    b: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Stable merge of two sorted 1-D arrays (Pallas SPM kernel)."""
    n = a.shape[0] + b.shape[0]
    tile, leaf = _resolve(n, jnp.result_type(a, b), tile, leaf)
    return _guard(
        "merge", (a, b), engine=engine, interpret=interpret,
        launch=lambda ar, eng, itp: _merge_launch(
            ar[0], ar[1], tile=tile, leaf=leaf, engine=eng, interpret=itp
        ),
        core=_core_merge, resort=_resort_merge, keys=(0, 1),
        meta=_meta(n, jnp.result_type(a, b), tile, leaf),
        verifier=_res.sorted_verifier(),
    )


@_JIT
def _merge_kv_launch(ak, av, bk, bv, *, tile, leaf, engine, interpret):
    n = ak.shape[0] + bk.shape[0]
    if n <= tile:
        return _mp.merge_kv(ak, av, bk, bv)
    return _kern.merge_kv_pallas(
        ak, av, bk, bv, tile=tile, leaf=leaf, engine=engine, interpret=interpret
    )


@kernel_contract(kind="merge", carries_values=True, masked_ranks=True)
def merge_kv(
    ak: jax.Array,
    av: jax.Array,
    bk: jax.Array,
    bv: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Stable key-value merge (Pallas SPM kernel)."""
    n = ak.shape[0] + bk.shape[0]
    tile, leaf = _resolve(n, jnp.result_type(ak, bk), tile, leaf)
    return _guard(
        "merge_kv", (ak, av, bk, bv), engine=engine, interpret=interpret,
        launch=lambda ar, eng, itp: _merge_kv_launch(
            *ar, tile=tile, leaf=leaf, engine=eng, interpret=itp
        ),
        core=_core_merge_kv, resort=_resort_merge_kv, keys=(0, 2),
        meta=_meta(n, jnp.result_type(ak, bk), tile, leaf),
        verifier=_res.sorted_verifier(),
    )


@_JIT
def _merge_batched_launch(a, b, *, tile, leaf, engine, interpret):
    n = a.shape[1] + b.shape[1]
    if n <= tile:
        return _bat.merge_batched(a, b)
    return _kern.merge_batched_pallas(
        a, b, tile=tile, leaf=leaf, engine=engine, interpret=interpret
    )


@kernel_contract(
    kind="merge",
    batched=True,
    tie_safe="keys-only: sentinel-tied pads are value-identical to the real "
             "key, so the merged row is unchanged whichever wins the tie",
)
def merge_batched(
    a: jax.Array,
    b: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Stable merge of ``B`` row pairs: ``(B, na) + (B, nb) -> (B, na+nb)``.

    One 2-D-grid kernel launch for the whole batch when rows are wide
    enough to tile; the fused pure-JAX batched merge otherwise.
    """
    n = a.shape[1] + b.shape[1]
    tile, leaf = _resolve(n, jnp.result_type(a, b), tile, leaf)
    return _guard(
        "merge_batched", (a, b), engine=engine, interpret=interpret,
        launch=lambda ar, eng, itp: _merge_batched_launch(
            ar[0], ar[1], tile=tile, leaf=leaf, engine=eng, interpret=itp
        ),
        core=_core_merge_batched, resort=_resort_merge_batched, keys=(0, 1),
        meta=_meta(n, jnp.result_type(a, b), tile, leaf, batch=a.shape[0]),
        verifier=_res.sorted_verifier(),
    )


@_JIT
def _merge_kv_batched_launch(ak, av, bk, bv, *, tile, leaf, engine, interpret):
    n = ak.shape[1] + bk.shape[1]
    if n <= tile:
        return _bat.merge_kv_batched(ak, av, bk, bv)
    return _kern.merge_kv_batched_pallas(
        ak, av, bk, bv, tile=tile, leaf=leaf, engine=engine, interpret=interpret
    )


@kernel_contract(kind="merge", batched=True, carries_values=True, masked_ranks=True)
def merge_kv_batched(
    ak: jax.Array,
    av: jax.Array,
    bk: jax.Array,
    bv: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Stable batched key-value merge (2-D-grid Pallas kernel when wide)."""
    n = ak.shape[1] + bk.shape[1]
    tile, leaf = _resolve(n, jnp.result_type(ak, bk), tile, leaf)
    return _guard(
        "merge_kv_batched", (ak, av, bk, bv), engine=engine, interpret=interpret,
        launch=lambda ar, eng, itp: _merge_kv_batched_launch(
            *ar, tile=tile, leaf=leaf, engine=eng, interpret=itp
        ),
        core=_core_merge_kv_batched, resort=_resort_merge_kv_batched, keys=(0, 2),
        meta=_meta(n, jnp.result_type(ak, bk), tile, leaf, batch=ak.shape[0]),
        verifier=_res.sorted_verifier(),
    )


@_JIT
def _merge_batched_ragged_launch(a, b, a_lens, b_lens, *, tile, leaf, engine, interpret):
    n = a.shape[1] + b.shape[1]
    if n <= tile:
        return _bat.merge_batched_ragged(a, b, a_lens, b_lens)
    return _kern.merge_batched_ragged_pallas(
        a, b, a_lens, b_lens, tile=tile, leaf=leaf, engine=engine,
        interpret=interpret,
    )


@kernel_contract(kind="merge", batched=True, ragged=True, masked_ranks=True)
def merge_batched_ragged(
    a: jax.Array,
    b: jax.Array,
    a_lens: jax.Array,
    b_lens: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Ragged batched merge: per-row valid lengths, sentinel-padded tails.

    Dispatches like :func:`merge_batched`: the fused pure-JAX ragged merge
    for narrow rows, the 2-D-grid ragged kernel (lengths via scalar
    prefetch) when rows are wide enough to tile.
    """
    bsz, na = a.shape
    nb = b.shape[1]
    n = na + nb
    tile, leaf = _resolve(n, jnp.result_type(a, b), tile, leaf)
    tracing = _res.is_tracing(a, b, a_lens, b_lens)
    return _guard(
        "merge_batched_ragged", (a, b, a_lens, b_lens),
        engine=engine, interpret=interpret,
        launch=lambda ar, eng, itp: _merge_batched_ragged_launch(
            *ar, tile=tile, leaf=leaf, engine=eng, interpret=itp
        ),
        core=_core_merge_batched_ragged, resort=_resort_merge_batched_ragged,
        keys=(0, 1),
        meta=_meta(n, jnp.result_type(a, b), tile, leaf, batch=bsz, ragged=True),
        verifier=None if tracing else _res.sorted_verifier(
            _ragged_lens_np(a_lens, b_lens, bsz, na, nb)
        ),
    )


@_JIT
def _merge_kv_batched_ragged_launch(
    ak, av, bk, bv, a_lens, b_lens, *, tile, leaf, engine, interpret
):
    n = ak.shape[1] + bk.shape[1]
    if n <= tile:
        return _bat.merge_kv_batched_ragged(ak, av, bk, bv, a_lens, b_lens)
    return _kern.merge_kv_batched_ragged_pallas(
        ak, av, bk, bv, a_lens, b_lens, tile=tile, leaf=leaf, engine=engine,
        interpret=interpret,
    )


@kernel_contract(
    kind="merge", batched=True, ragged=True, carries_values=True, masked_ranks=True
)
def merge_kv_batched_ragged(
    ak: jax.Array,
    av: jax.Array,
    bk: jax.Array,
    bv: jax.Array,
    a_lens: jax.Array,
    b_lens: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Ragged batched key-value merge (2-D-grid ragged kernel when wide)."""
    bsz, na = ak.shape
    nb = bk.shape[1]
    n = na + nb
    tile, leaf = _resolve(n, jnp.result_type(ak, bk), tile, leaf)
    tracing = _res.is_tracing(ak, av, bk, bv, a_lens, b_lens)
    return _guard(
        "merge_kv_batched_ragged", (ak, av, bk, bv, a_lens, b_lens),
        engine=engine, interpret=interpret,
        launch=lambda ar, eng, itp: _merge_kv_batched_ragged_launch(
            *ar, tile=tile, leaf=leaf, engine=eng, interpret=itp
        ),
        core=_core_merge_kv_batched_ragged,
        resort=_resort_merge_kv_batched_ragged, keys=(0, 2),
        meta=_meta(n, jnp.result_type(ak, bk), tile, leaf, batch=bsz, ragged=True),
        verifier=None if tracing else _res.sorted_verifier(
            _ragged_lens_np(a_lens, b_lens, bsz, na, nb)
        ),
    )


# ---------------------------------------------------------------------------
# Sorts: flat rounds, padding hoisted out of the loop
# ---------------------------------------------------------------------------


def _sort_rounds(flat: jax.Array, m: int, tile: int, leaf: int, engine: str, interpret: bool) -> jax.Array:
    """Bottom-up merge-sort rounds over a flat ``(B * m,)`` buffer of
    width-1 runs (``m`` = per-row pow2 width; pairs never straddle a row
    because ``m`` is a multiple of every round width).

    Narrow rounds (``2 * width <= tile``) are fused pure-JAX batched
    merges on reshaped views; wide rounds are flat-kernel launches
    sharing ONE sentinel tail appended here, once — the padding hoist
    that used to happen per round inside ``_prepare_batched``.
    """
    width = 1
    while width < m and 2 * width <= tile:
        runs = flat.reshape(-1, 2, width)
        flat = _bat.merge_batched(runs[:, 0], runs[:, 1]).reshape(-1)
        width *= 2
    if width < m:
        total = flat.shape[0]
        xf = jnp.concatenate(
            [flat, jnp.full((tile,), _mp.max_sentinel(flat.dtype), flat.dtype)]
        )
        while width < m:
            xf = _kern.sort_round_pallas(
                xf, width, tile=tile, leaf=leaf, engine=engine, interpret=interpret
            )
            width *= 2
        flat = xf[:total]
    return flat


def _sort_rounds_kv(
    kflat: jax.Array, vflat: jax.Array, m: int, tile: int, leaf: int, engine: str, interpret: bool
) -> Tuple[jax.Array, jax.Array]:
    """Key-value :func:`_sort_rounds` (values' hoisted tail is zeros)."""
    width = 1
    while width < m and 2 * width <= tile:
        kr = kflat.reshape(-1, 2, width)
        vr = vflat.reshape(-1, 2, width)
        kflat, vflat = _bat.merge_kv_batched(kr[:, 0], vr[:, 0], kr[:, 1], vr[:, 1])
        kflat, vflat = kflat.reshape(-1), vflat.reshape(-1)
        width *= 2
    if width < m:
        total = kflat.shape[0]
        kf = jnp.concatenate(
            [kflat, jnp.full((tile,), _mp.max_sentinel(kflat.dtype), kflat.dtype)]
        )
        vf = jnp.concatenate([vflat, jnp.zeros((tile,), vflat.dtype)])
        while width < m:
            kf, vf = _kern.sort_round_kv_pallas(
                kf, vf, width, tile=tile, leaf=leaf, engine=engine, interpret=interpret
            )
            width *= 2
        kflat, vflat = kf[:total], vf[:total]
    return kflat, vflat


# --- raw (non-differentiable) sort bodies -----------------------------------


def _keyed(k: jax.Array) -> jax.Array:
    """Keys the merge network actually compares: int total-order keys for
    floats (NaN-deterministic), the raw keys otherwise."""
    return _mp.total_order_keys(k) if _inexact(k.dtype) else k


def _sort_impl(x, n, tile, leaf, engine, interp):
    if _inexact(x.dtype):
        # kv-carry: compare int total-order keys, ride the floats as values
        _, out = _sort_kv_impl(
            _mp.total_order_keys(x), x, n, tile, leaf, engine, interp
        )
        return out
    xp = _mp._pad_pow2(x, _mp.max_sentinel(x.dtype))
    return _sort_rounds(xp, xp.shape[0], tile, leaf, engine, interp)[:n]


def _sort_kv_impl(keys, values, n, tile, leaf, engine, interp):
    kp = _mp._pad_pow2(keys, _mp.max_sentinel(keys.dtype))
    vp = _mp._pad_pow2(values, jnp.zeros((), values.dtype))
    ks, vs = _sort_rounds_kv(kp, vp, kp.shape[0], tile, leaf, engine, interp)
    return ks[:n], vs[:n]


def _sort_batched_impl(x, n, tile, leaf, engine, interp):
    bsz = x.shape[0]
    if _inexact(x.dtype):
        _, out = _sort_kv_batched_impl(
            _mp.total_order_keys(x), x, n, tile, leaf, engine, interp
        )
        return out
    xp = _bat._pad_rows_pow2(x, _mp.max_sentinel(x.dtype))
    m = xp.shape[1]
    out = _sort_rounds(xp.reshape(-1), m, tile, leaf, engine, interp)
    return out.reshape(bsz, m)[:, :n]


def _sort_kv_batched_impl(keys, values, n, tile, leaf, engine, interp):
    bsz = keys.shape[0]
    kp = _bat._pad_rows_pow2(keys, _mp.max_sentinel(keys.dtype))
    vp = _bat._pad_rows_pow2(values, jnp.zeros((), values.dtype))
    m = kp.shape[1]
    ks, vs = _sort_rounds_kv(
        kp.reshape(-1), vp.reshape(-1), m, tile, leaf, engine, interp
    )
    return ks.reshape(bsz, m)[:, :n], vs.reshape(bsz, m)[:, :n]


# --- permutation-transpose VJP glue -----------------------------------------


def _inexact(dtype) -> bool:
    return jnp.issubdtype(jnp.dtype(dtype), jnp.inexact)


def _float0(shape):
    """float0 cotangent zeros — what custom_vjp requires for int primals."""
    return np.zeros(shape, _jdtypes.float0)


def _iota_like(x) -> jax.Array:
    """Row-index payload whose sorted order IS the stable argsort."""
    if x.ndim == 1:
        return jnp.arange(x.shape[0], dtype=jnp.int32)
    return jnp.broadcast_to(
        jnp.arange(x.shape[-1], dtype=jnp.int32)[None, :], x.shape
    )


def _scatter_inverse(perm: jax.Array, ct: jax.Array) -> jax.Array:
    """Permutation transpose: route output cotangents back to input slots.

    ``perm`` is a (batched) permutation — each source index appears
    exactly once — so the scatter is an exact inverse gather in any
    dtype (no accumulation happens).
    """
    if perm.ndim == 1:
        return jnp.zeros(perm.shape, ct.dtype).at[perm].set(ct)
    rows = jnp.arange(perm.shape[0], dtype=jnp.int32)[:, None]
    return jnp.zeros(perm.shape, ct.dtype).at[rows, perm].set(ct)


# --- jitted sort bodies (the guarded wrappers' primary attempts) ------------


@_JIT
def _sort(x, *, tile, leaf, engine, interpret):
    n = x.shape[0]
    if not _inexact(x.dtype):
        return _sort_impl(x, n, tile, leaf, engine, interpret)

    @jax.custom_vjp
    def f(xx):
        return _sort_impl(xx, n, tile, leaf, engine, interpret)

    def fwd(xx):
        _, perm = _sort_kv_impl(
            _mp.total_order_keys(xx), _iota_like(xx), n, tile, leaf, engine, interpret
        )
        # stability makes xx[perm] bit-identical to the kernel's key output
        return jnp.take(xx, perm), perm

    def bwd(perm, dy):
        return (_scatter_inverse(perm, dy),)

    f.defvjp(fwd, bwd)
    return f(x)


@_JIT
def _sort_kv(keys, values, *, tile, leaf, engine, interpret):
    n = keys.shape[0]
    kx, vx = _inexact(keys.dtype), _inexact(values.dtype)
    if not (kx or vx):
        return _sort_kv_impl(keys, values, n, tile, leaf, engine, interpret)

    @jax.custom_vjp
    def f(k, v):
        if kx:
            # float keys: permute through the int total-order keys and
            # gather the original bit patterns (NaN-deterministic)
            _, perm = _sort_kv_impl(
                _keyed(k), _iota_like(k), n, tile, leaf, engine, interpret
            )
            return jnp.take(k, perm), jnp.take(v, perm)
        return _sort_kv_impl(k, v, n, tile, leaf, engine, interpret)

    def fwd(k, v):
        _, perm = _sort_kv_impl(
            _keyed(k), _iota_like(k), n, tile, leaf, engine, interpret
        )
        # stability makes the perm-gathers bit-identical to the kernel output
        return (jnp.take(k, perm), jnp.take(v, perm)), perm

    def bwd(perm, cts):
        dks, dvs = cts
        dk = _scatter_inverse(perm, dks) if kx else _float0((n,))
        dv = _scatter_inverse(perm, dvs) if vx else _float0((n,))
        return dk, dv

    f.defvjp(fwd, bwd)
    return f(keys, values)


@_JIT
def _sort_batched(x, *, tile, leaf, engine, interpret):
    bsz, n = x.shape
    if not _inexact(x.dtype):
        return _sort_batched_impl(x, n, tile, leaf, engine, interpret)

    @jax.custom_vjp
    def f(xx):
        return _sort_batched_impl(xx, n, tile, leaf, engine, interpret)

    def fwd(xx):
        _, perm = _sort_kv_batched_impl(
            _mp.total_order_keys(xx), _iota_like(xx), n, tile, leaf, engine, interpret
        )
        rows = jnp.arange(bsz, dtype=jnp.int32)[:, None]
        return xx[rows, perm], perm

    def bwd(perm, dy):
        return (_scatter_inverse(perm, dy),)

    f.defvjp(fwd, bwd)
    return f(x)


@_JIT
def _sort_kv_batched(keys, values, *, tile, leaf, engine, interpret):
    bsz, n = keys.shape
    kx, vx = _inexact(keys.dtype), _inexact(values.dtype)
    if not (kx or vx):
        return _sort_kv_batched_impl(keys, values, n, tile, leaf, engine, interpret)

    @jax.custom_vjp
    def f(k, v):
        if kx:
            _, perm = _sort_kv_batched_impl(
                _keyed(k), _iota_like(k), n, tile, leaf, engine, interpret
            )
            rows = jnp.arange(bsz, dtype=jnp.int32)[:, None]
            return k[rows, perm], v[rows, perm]
        return _sort_kv_batched_impl(k, v, n, tile, leaf, engine, interpret)

    def fwd(k, v):
        _, perm = _sort_kv_batched_impl(
            _keyed(k), _iota_like(k), n, tile, leaf, engine, interpret
        )
        rows = jnp.arange(bsz, dtype=jnp.int32)[:, None]
        # stability makes the perm-gathers bit-identical to the kernel output
        return (k[rows, perm], v[rows, perm]), perm

    def bwd(perm, cts):
        dks, dvs = cts
        dk = _scatter_inverse(perm, dks) if kx else _float0((bsz, n))
        dv = _scatter_inverse(perm, dvs) if vx else _float0((bsz, n))
        return dk, dv

    f.defvjp(fwd, bwd)
    return f(keys, values)


@kernel_contract(kind="sort", masked_ranks=True, pow2_tile=True, differentiable=True)
def sort(
    x: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Bottom-up merge sort whose wide rounds run on the flat round kernel.

    Every round is ONE call: narrow rounds (2*width <= tile) use the fused
    pure-JAX batched merge, wide rounds the flat ``(pair, tile)`` kernel —
    no Python-level loop over run pairs, and the pow2 + sentinel padding
    is built once per sort, not re-appended every round.

    Differentiable: under AD the forward runs the kv kernel with an iota
    payload to capture the stable argsort, and the backward is one
    inverse-gather scatter — the exact permutation transpose.
    """
    n = x.shape[0]
    if n <= 1:
        return x
    tile, leaf = _sort_tile(n, x.dtype, tile, leaf)
    return _guard(
        "sort", (x,), engine=engine, interpret=interpret,
        launch=lambda ar, eng, itp: _sort(
            ar[0], tile=tile, leaf=leaf, engine=eng, interpret=itp
        ),
        core=_core_sort, keys=(0,),
        meta=_meta(n, x.dtype, tile, leaf),
        verifier=_res.sorted_verifier(),
    )


@kernel_contract(
    kind="sort", carries_values=True, masked_ranks=True, pow2_tile=True,
    differentiable=True,
)
def sort_kv(
    keys: jax.Array,
    values: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Stable key-value merge sort; wide rounds on the flat round kernel.

    Differentiable in both keys and values via the permutation-transpose
    VJP (int operands get float0 cotangents, per custom_vjp convention).
    """
    n = keys.shape[0]
    if n <= 1:
        return keys, values
    tile, leaf = _sort_tile(n, keys.dtype, tile, leaf)
    return _guard(
        "sort_kv", (keys, values), engine=engine, interpret=interpret,
        launch=lambda ar, eng, itp: _sort_kv(
            ar[0], ar[1], tile=tile, leaf=leaf, engine=eng, interpret=itp
        ),
        core=_core_sort_kv, keys=(0,),
        meta=_meta(n, keys.dtype, tile, leaf),
        verifier=_res.sorted_verifier(),
    )


@kernel_contract(
    kind="sort", batched=True, masked_ranks=True, pow2_tile=True,
    differentiable=True,
)
def sort_batched(
    x: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Sort every row of ``(B, n)`` ascending; rows ride the same flat
    rounds as :func:`sort` (the batch axis is folded into the run-pair
    axis, so per-round launch count is independent of ``B``).
    Differentiable via the per-row permutation-transpose VJP."""
    bsz, n = x.shape
    if n <= 1:
        return x
    tile, leaf = _sort_tile(n, x.dtype, tile, leaf)
    return _guard(
        "sort_batched", (x,), engine=engine, interpret=interpret,
        launch=lambda ar, eng, itp: _sort_batched(
            ar[0], tile=tile, leaf=leaf, engine=eng, interpret=itp
        ),
        core=_core_sort_batched, keys=(0,),
        meta=_meta(n, x.dtype, tile, leaf, batch=bsz),
        verifier=_res.sorted_verifier(),
    )


@kernel_contract(
    kind="sort", batched=True, carries_values=True, masked_ranks=True,
    pow2_tile=True, differentiable=True,
)
def sort_kv_batched(
    keys: jax.Array,
    values: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Row-wise stable key-value sort of ``(B, n)`` keys (ascending),
    kernel-backed like :func:`sort_batched` and differentiable in both
    operands via the per-row permutation-transpose VJP."""
    bsz, n = keys.shape
    if n <= 1:
        return keys, values
    tile, leaf = _sort_tile(n, keys.dtype, tile, leaf)
    return _guard(
        "sort_kv_batched", (keys, values), engine=engine, interpret=interpret,
        launch=lambda ar, eng, itp: _sort_kv_batched(
            ar[0], ar[1], tile=tile, leaf=leaf, engine=eng, interpret=itp
        ),
        core=_core_sort_kv_batched, keys=(0,),
        meta=_meta(n, keys.dtype, tile, leaf, batch=bsz),
        verifier=_res.sorted_verifier(),
    )


def _merge_k_rounds(runs, lens, tile, leaf, engine, interpret):
    """The k-way tournament body: ``ceil(log2 k)`` ragged batched rounds."""
    k, n = runs.shape
    sent = _mp.max_sentinel(runs.dtype)
    run_lens = (
        jnp.full((k,), n, jnp.int32) if lens is None else _bat._as_lens(lens, k, n)
    )
    stacked = _bat._mask_rows(runs, run_lens, sent)
    target = 1 << max(0, (k - 1).bit_length())
    if target != k:
        pad = jnp.full((target - k, n), sent, stacked.dtype)
        stacked = jnp.concatenate([stacked, pad], axis=0)
        run_lens = jnp.concatenate([run_lens, jnp.zeros((target - k,), jnp.int32)])
    while stacked.shape[0] > 1:
        stacked = merge_batched_ragged(
            stacked[0::2],
            stacked[1::2],
            run_lens[0::2],
            run_lens[1::2],
            tile=tile,
            leaf=leaf,
            engine=engine,
            interpret=interpret,
        )
        run_lens = run_lens[0::2] + run_lens[1::2]
    # pow2 pad rows only ever append sentinels, so the (k * n,) prefix
    # holds every valid element — same output width as the core forms
    return stacked[0][: k * n]


@kernel_contract(kind="merge_k", ragged=True, masked_ranks=True)
def merge_k(
    runs: jax.Array,
    lens: Optional[jax.Array] = None,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """k-way tournament merge whose rounds run on the ragged batched kernel.

    Same contract as :func:`repro.core.batched.merge_k` restricted to a
    stacked ``(k, n)`` runs array (stable with lower-run priority; ``lens``
    optionally gives per-run valid lengths; output is always the
    ``(k * n,)`` merged valid prefix followed by sentinel padding — a
    traced ``lens`` forbids trimming further).  Each of the
    ``ceil(log2 k)`` tournament rounds is one :func:`merge_batched_ragged`
    call, i.e. the hierarchical tile engine once the runs are wide enough
    to tile — this is ``distributed_sort``'s bucket combine for
    ``local_sort="pallas", combine="tournament"``.

    The rounds are themselves guarded calls, so this wrapper's own chain
    only adds the direct core tournament as a terminal oracle.
    """
    runs = jnp.asarray(runs)
    if runs.ndim != 2:
        raise ValueError(f"expected (k, n) runs, got shape {runs.shape}")
    k, n = runs.shape
    if not _res.guard_enabled() or _res.is_tracing(runs, lens):
        return _merge_k_rounds(runs, lens, tile, leaf, engine, interpret)
    idx = _faults.next_index("merge_k")
    if lens is None:
        total = k * n
    else:
        total = int(np.clip(np.asarray(lens, dtype=np.int64).reshape(-1), 0, n).sum())
    return _res.guarded_call(
        "merge_k",
        [
            (f"rounds-{engine}",
             lambda: _merge_k_rounds(runs, lens, tile, leaf, engine, interpret)),
            ("core", lambda: _core_merge_k(runs, lens)),
        ],
        index=idx,
        meta=_meta(k * n, runs.dtype, batch=k, ragged=True),
        verifier=_res.sorted_verifier(np.asarray([total])),
    )


@_JITK
def _topk_batched(x, *, k, tile, leaf, engine, interpret):
    bsz, n = x.shape

    def _primal(xx):
        _, perm = _sort_kv_batched_impl(
            _keyed(_mp.flip_desc(xx)), _iota_like(xx), n, tile, leaf, engine, interpret
        )
        top_idx = perm[:, :k]
        return jnp.take_along_axis(xx, top_idx, axis=1), top_idx

    if not _inexact(x.dtype):
        return _primal(x)

    @jax.custom_vjp
    def f(xx):
        return _primal(xx)

    def fwd(xx):
        vals, top_idx = _primal(xx)
        return (vals, top_idx), top_idx

    def bwd(top_idx, cts):
        dvals, _ = cts  # index cotangent is float0
        rows = jnp.arange(bsz, dtype=jnp.int32)[:, None]
        return (jnp.zeros((bsz, n), dvals.dtype).at[rows, top_idx].set(dvals),)

    f.defvjp(fwd, bwd)
    return f(x)


@kernel_contract(
    kind="topk", batched=True, carries_values=True, masked_ranks=True,
    pow2_tile=True, differentiable=True,
)
def topk_batched(
    x: jax.Array,
    k: int,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Row-wise descending top-k on the kernel-backed batched kv-sort.

    Same contract as :func:`repro.core.batched.topk_batched` (stable,
    ``lax.top_k`` tie-breaking, exact at ``iinfo.min`` via
    ``flip_desc``), but the sort rounds run on the flat round kernel
    with tuned ``(tile, leaf)`` — the serving sampler's wide-vocab path.
    NaN candidates rank below every real value (total-order keys).
    Differentiable: the backward scatters the k value-cotangents back to
    their source columns (one exact inverse gather).
    """
    bsz, n = x.shape
    k = min(k, n)
    tile, leaf = _sort_tile(n, x.dtype, tile, leaf)
    return _guard(
        "topk_batched", (x,), engine=engine, interpret=interpret,
        launch=lambda ar, eng, itp: _topk_batched(
            ar[0], k=k, tile=tile, leaf=leaf, engine=eng, interpret=itp
        ),
        core=lambda xx: _core_topk_batched(xx, k), keys=(0,),
        meta=_meta(n, x.dtype, tile, leaf, batch=bsz),
        verifier=_res.topk_verifier(),
    )


@_JITK
def _topk_batched_ragged(x, lens, *, k, tile, leaf, engine, interpret):
    bsz, n = x.shape

    def _primal(xx, ln):
        keys = _keyed(_mp.flip_desc(xx))
        keys = _bat._mask_rows(keys, ln, _mp.max_sentinel(keys.dtype))
        _, perm = _sort_kv_batched_impl(
            keys, _iota_like(xx), n, tile, leaf, engine, interpret
        )
        top_idx = perm[:, :k]
        vals = jnp.take_along_axis(xx, top_idx, axis=1)
        slot_valid = jnp.arange(k, dtype=jnp.int32)[None, :] < ln[:, None]
        vals = jnp.where(slot_valid, vals, _mp.min_sentinel(xx.dtype))
        top_idx = jnp.where(slot_valid, top_idx, -1)
        return vals, top_idx

    if not _inexact(x.dtype):
        return _primal(x, lens)

    @jax.custom_vjp
    def f(xx, ln):
        return _primal(xx, ln)

    def fwd(xx, ln):
        vals, top_idx = _primal(xx, ln)
        return (vals, top_idx), top_idx

    def bwd(top_idx, cts):
        dvals, _ = cts
        valid = top_idx >= 0
        safe_idx = jnp.where(valid, top_idx, 0)
        contrib = jnp.where(valid, dvals, jnp.zeros((), dvals.dtype))
        rows = jnp.arange(bsz, dtype=jnp.int32)[:, None]
        # .add (not .set): masked slots alias column 0 with zero contribution
        dx = jnp.zeros((bsz, n), dvals.dtype).at[rows, safe_idx].add(contrib)
        return dx, _float0((bsz,))

    f.defvjp(fwd, bwd)
    return f(x, lens)


@kernel_contract(
    kind="topk", batched=True, ragged=True, carries_values=True,
    masked_ranks=True, pow2_tile=True, differentiable=True,
)
def topk_batched_ragged(
    x: jax.Array,
    k: int,
    lens: jax.Array,
    *,
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    engine: str = _kern.DEFAULT_ENGINE,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Ragged row-wise descending top-k, kernel-backed.

    Contract matches :func:`repro.core.batched.topk_batched_ragged`
    exactly (masked slots: index ``-1``, dtype-min value); the underlying
    sort is the same sentinel-mask-then-sort reduction the core ragged
    kv-sort uses, so padded rows are bit-identical to their truncations.
    Differentiable: cotangents of masked (sentinel) slots are provably
    zeroed — only valid slots scatter back, so rows shorter than ``k``
    get exactly the gradient their truncation would.
    """
    bsz, n = x.shape
    k = min(k, n)
    lens = _bat._as_lens(lens, bsz, n)
    tile, leaf = _sort_tile(n, x.dtype, tile, leaf)
    return _guard(
        "topk_batched_ragged", (x, lens), engine=engine, interpret=interpret,
        launch=lambda ar, eng, itp: _topk_batched_ragged(
            ar[0], ar[1], k=k, tile=tile, leaf=leaf, engine=eng, interpret=itp
        ),
        core=lambda xx, ln: _core_topk_batched_ragged(xx, k, ln), keys=(0,),
        meta=_meta(n, x.dtype, tile, leaf, batch=bsz, ragged=True),
        verifier=_res.topk_verifier(),
    )
