"""Jitted public wrappers around the Merge Path Pallas kernels.

``merge`` / ``merge_kv`` / ``sort`` / ``sort_kv`` dispatch to the Pallas
SPM kernel when the problem is big enough to tile, and to the pure-JAX
core otherwise.  ``merge_batched`` / ``merge_kv_batched`` are the batched
(leading batch axis) forms on the 2-D ``(batch, tile)`` grid kernel —
one launch for the whole batch; the sorts route their wide rounds
through them so a sort round is a single kernel launch regardless of how
many run pairs it merges.  ``interpret`` defaults to True because this
build environment is CPU-only; on a real TPU pass ``interpret=False``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import batched as _bat
from repro.core import merge_path as _mp
from . import merge_path as _kern


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def merge(
    a: jax.Array, b: jax.Array, *, tile: int = _kern.DEFAULT_TILE, interpret: bool = True
) -> jax.Array:
    """Stable merge of two sorted 1-D arrays (Pallas SPM kernel)."""
    if a.shape[0] + b.shape[0] <= tile:
        return _mp.merge(a, b)
    return _kern.merge_pallas(a, b, tile=tile, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def merge_kv(
    ak: jax.Array,
    av: jax.Array,
    bk: jax.Array,
    bv: jax.Array,
    *,
    tile: int = _kern.DEFAULT_TILE,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Stable key-value merge (Pallas SPM kernel)."""
    if ak.shape[0] + bk.shape[0] <= tile:
        return _mp.merge_kv(ak, av, bk, bv)
    return _kern.merge_kv_pallas(ak, av, bk, bv, tile=tile, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def merge_batched(
    a: jax.Array, b: jax.Array, *, tile: int = _kern.DEFAULT_TILE, interpret: bool = True
) -> jax.Array:
    """Stable merge of ``B`` row pairs: ``(B, na) + (B, nb) -> (B, na+nb)``.

    One 2-D-grid kernel launch for the whole batch when rows are wide
    enough to tile; the fused pure-JAX batched merge otherwise.
    """
    if a.shape[1] + b.shape[1] <= tile:
        return _bat.merge_batched(a, b)
    return _kern.merge_batched_pallas(a, b, tile=tile, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def merge_kv_batched(
    ak: jax.Array,
    av: jax.Array,
    bk: jax.Array,
    bv: jax.Array,
    *,
    tile: int = _kern.DEFAULT_TILE,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Stable batched key-value merge (2-D-grid Pallas kernel when wide)."""
    if ak.shape[1] + bk.shape[1] <= tile:
        return _bat.merge_kv_batched(ak, av, bk, bv)
    return _kern.merge_kv_batched_pallas(ak, av, bk, bv, tile=tile, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def merge_batched_ragged(
    a: jax.Array,
    b: jax.Array,
    a_lens: jax.Array,
    b_lens: jax.Array,
    *,
    tile: int = _kern.DEFAULT_TILE,
    interpret: bool = True,
) -> jax.Array:
    """Ragged batched merge: per-row valid lengths, sentinel-padded tails.

    Dispatches like :func:`merge_batched`: the fused pure-JAX ragged merge
    for narrow rows, the 2-D-grid ragged kernel (lengths via scalar
    prefetch) when rows are wide enough to tile.
    """
    if a.shape[1] + b.shape[1] <= tile:
        return _bat.merge_batched_ragged(a, b, a_lens, b_lens)
    return _kern.merge_batched_ragged_pallas(
        a, b, a_lens, b_lens, tile=tile, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def merge_kv_batched_ragged(
    ak: jax.Array,
    av: jax.Array,
    bk: jax.Array,
    bv: jax.Array,
    a_lens: jax.Array,
    b_lens: jax.Array,
    *,
    tile: int = _kern.DEFAULT_TILE,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Ragged batched key-value merge (2-D-grid ragged kernel when wide)."""
    if ak.shape[1] + bk.shape[1] <= tile:
        return _bat.merge_kv_batched_ragged(ak, av, bk, bv, a_lens, b_lens)
    return _kern.merge_kv_batched_ragged_pallas(
        ak, av, bk, bv, a_lens, b_lens, tile=tile, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def sort(x: jax.Array, *, tile: int = _kern.DEFAULT_TILE, interpret: bool = True) -> jax.Array:
    """Bottom-up merge sort whose wide rounds run on the batched Pallas kernel.

    Every round is ONE call: narrow rounds (2*width <= tile) use the fused
    pure-JAX batched merge, wide rounds the 2-D ``(pairs, tile)`` grid
    kernel — no Python-level loop over run pairs.
    """
    n = x.shape[0]
    if n <= 1:
        return x
    xp = _mp._pad_pow2(x, _mp.max_sentinel(x.dtype))
    m = xp.shape[0]
    width = 1
    while width < m:
        runs = xp.reshape(-1, 2, width)
        if 2 * width <= tile:
            xp = _bat.merge_batched(runs[:, 0], runs[:, 1]).reshape(-1)
        else:
            xp = _kern.merge_batched_pallas(
                runs[:, 0], runs[:, 1], tile=tile, interpret=interpret
            ).reshape(-1)
        width *= 2
    return xp[:n]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def sort_kv(
    keys: jax.Array,
    values: jax.Array,
    *,
    tile: int = _kern.DEFAULT_TILE,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Stable key-value merge sort; wide rounds on the batched Pallas kernel."""
    n = keys.shape[0]
    if n <= 1:
        return keys, values
    kp = _mp._pad_pow2(keys, _mp.max_sentinel(keys.dtype))
    vp = _mp._pad_pow2(values, jnp.zeros((), values.dtype))
    m = kp.shape[0]
    width = 1
    while width < m:
        kr = kp.reshape(-1, 2, width)
        vr = vp.reshape(-1, 2, width)
        if 2 * width <= tile:
            kp, vp = _bat.merge_kv_batched(kr[:, 0], vr[:, 0], kr[:, 1], vr[:, 1])
        else:
            kp, vp = _kern.merge_kv_batched_pallas(
                kr[:, 0], vr[:, 0], kr[:, 1], vr[:, 1], tile=tile, interpret=interpret
            )
        kp, vp = kp.reshape(-1), vp.reshape(-1)
        width *= 2
    return kp[:n], vp[:n]
