"""Jitted public wrappers around the Merge Path Pallas kernels.

``merge`` / ``merge_kv`` / ``sort`` / ``sort_kv`` dispatch to the Pallas
SPM kernel when the problem is big enough to tile, and to the pure-JAX
core otherwise.  ``interpret`` defaults to True because this build
environment is CPU-only; on a real TPU pass ``interpret=False``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import merge_path as _mp
from . import merge_path as _kern


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def merge(
    a: jax.Array, b: jax.Array, *, tile: int = _kern.DEFAULT_TILE, interpret: bool = True
) -> jax.Array:
    """Stable merge of two sorted 1-D arrays (Pallas SPM kernel)."""
    if a.shape[0] + b.shape[0] <= tile:
        return _mp.merge(a, b)
    return _kern.merge_pallas(a, b, tile=tile, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def merge_kv(
    ak: jax.Array,
    av: jax.Array,
    bk: jax.Array,
    bv: jax.Array,
    *,
    tile: int = _kern.DEFAULT_TILE,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Stable key-value merge (Pallas SPM kernel)."""
    if ak.shape[0] + bk.shape[0] <= tile:
        return _mp.merge_kv(ak, av, bk, bv)
    return _kern.merge_kv_pallas(ak, av, bk, bv, tile=tile, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def sort(x: jax.Array, *, tile: int = _kern.DEFAULT_TILE, interpret: bool = True) -> jax.Array:
    """Bottom-up merge sort whose top rounds use the Pallas merge kernel."""
    n = x.shape[0]
    if n <= 1:
        return x
    xp = _mp._pad_pow2(x, _mp.max_sentinel(x.dtype))
    m = xp.shape[0]
    width = 1
    while width < m:
        runs = xp.reshape(-1, 2, width)
        if 2 * width <= tile:
            xp = jax.vmap(_mp.merge)(runs[:, 0], runs[:, 1]).reshape(-1)
        else:
            pairs = [
                _kern.merge_pallas(runs[i, 0], runs[i, 1], tile=tile, interpret=interpret)
                for i in range(runs.shape[0])
            ]
            xp = jnp.concatenate(pairs)
        width *= 2
    return xp[:n]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def sort_kv(
    keys: jax.Array,
    values: jax.Array,
    *,
    tile: int = _kern.DEFAULT_TILE,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Stable key-value merge sort; top rounds on the Pallas kernel."""
    n = keys.shape[0]
    if n <= 1:
        return keys, values
    kp = _mp._pad_pow2(keys, _mp.max_sentinel(keys.dtype))
    vp = _mp._pad_pow2(values, jnp.zeros((), values.dtype))
    m = kp.shape[0]
    width = 1
    while width < m:
        kr = kp.reshape(-1, 2, width)
        vr = vp.reshape(-1, 2, width)
        if 2 * width <= tile:
            kp, vp = jax.vmap(_mp.merge_kv)(kr[:, 0], vr[:, 0], kr[:, 1], vr[:, 1])
            kp, vp = kp.reshape(-1), vp.reshape(-1)
        else:
            ks, vs = [], []
            for i in range(kr.shape[0]):
                ko, vo = _kern.merge_kv_pallas(
                    kr[i, 0], vr[i, 0], kr[i, 1], vr[i, 1], tile=tile, interpret=interpret
                )
                ks.append(ko)
                vs.append(vo)
            kp, vp = jnp.concatenate(ks), jnp.concatenate(vs)
        width *= 2
    return kp[:n], vp[:n]
