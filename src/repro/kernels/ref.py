"""Pure-jnp oracles for the Merge Path kernels.

These are the ground truth the Pallas kernels are validated against
(interpret-mode allclose sweeps in ``tests/test_kernels.py``).  They use
only ``jax.lax.sort`` / ``jnp`` primitives — no Pallas, no Merge Path
machinery — so a bug in the kernel cannot be mirrored here.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def merge_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Stable merge oracle (values only): sort of the concatenation."""
    dtype = jnp.result_type(a, b)
    return jnp.sort(jnp.concatenate([a.astype(dtype), b.astype(dtype)]))


def merge_kv_ref(
    ak: jax.Array, av: jax.Array, bk: jax.Array, bv: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Stable key-value merge oracle with A-priority.

    ``lax.sort`` with ``is_stable=True`` over the concatenation [A; B]
    preserves A-before-B order among equal keys, which is exactly the
    paper's path convention (down-moves on ties).
    """
    kd = jnp.result_type(ak, bk)
    vd = jnp.result_type(av, bv)
    keys = jnp.concatenate([ak.astype(kd), bk.astype(kd)])
    vals = jnp.concatenate([av.astype(vd), bv.astype(vd)])
    ks, vs = jax.lax.sort((keys, vals), dimension=0, is_stable=True, num_keys=1)
    return ks, vs


def sort_ref(x: jax.Array) -> jax.Array:
    return jnp.sort(x)


def sort_kv_ref(keys: jax.Array, values: jax.Array) -> Tuple[jax.Array, jax.Array]:
    return jax.lax.sort((keys, values), dimension=0, is_stable=True, num_keys=1)
