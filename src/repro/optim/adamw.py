"""AdamW with cosine schedule, global-norm clip, and optional gradient
compression for the cross-pod all-reduce (top-k error feedback / int8).

Params are stored fp32 (masters); the model casts to cfg.dtype at use.
Optimizer state is sharded like the params (FSDP), see parallel.sharding.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


def cosine_schedule(tcfg: TrainConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(1, tcfg.warmup_steps), 1.0)
    prog = jnp.clip(
        (step - tcfg.warmup_steps) / jnp.maximum(1, tcfg.total_steps - tcfg.warmup_steps),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return tcfg.learning_rate * warm * (0.1 + 0.9 * cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros(), "v": zeros()}


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(
    tcfg: TrainConfig,
    params,
    grads,
    opt_state: Dict[str, Any],
    step: jax.Array,
):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
    lr = cosine_schedule(tcfg, step)
    b1, b2, eps, wd = tcfg.beta1, tcfg.beta2, tcfg.eps, tcfg.weight_decay
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t

    def upd(p, g, m, v):
        p32 = p.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + eps) + wd * p32
        return (p32 - lr * step_).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v}, metrics
