"""Checkpointing: atomic, async, elastic-reshard restore.

Layout per step::

    <dir>/step_<k>.tmp/...   (written)
    <dir>/step_<k>/          (atomic rename on completion)
        manifest.json        {step, leaf paths, shapes, dtypes}
        arrays.npz           flat leaf -> array

* **atomic**: a crashed writer never leaves a loadable-but-corrupt step;
  restore picks the newest complete directory.
* **async**: ``save(..., blocking=False)`` snapshots to host memory and
  writes in a daemon thread — the train loop keeps stepping.
* **elastic**: ``restore(..., shardings=...)`` re-device_puts onto ANY
  mesh (different device count / topology than the writer's) — this is
  the restart path after losing nodes.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- write ---------------------------------------------------------
    def save(self, step: int, state, blocking: bool = True) -> None:
        flat = _flatten(state)  # host snapshot (device->host copy happens here)
        if blocking:
            self._write(step, flat)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, flat), daemon=True
            )
            self._thread.start()

    def _write_guarded(self, step, flat):
        try:
            self._write(step, flat)
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def _write(self, step: int, flat: Dict[str, np.ndarray]) -> None:
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from err

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -- read ----------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, step: Optional[int] = None, shardings=None):
        """Restore into the structure of ``like`` (a state pytree or
        eval_shape thereof); optionally device_put with new ``shardings``
        (same tree structure) — the elastic-remesh path."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        data = np.load(os.path.join(path, "arrays.npz"))
        flat_like = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for kpath, leaf in flat_like[0]:
            key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kpath)
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs state {leaf.shape}")
            leaves.append(arr)
        state = jax.tree_util.tree_unflatten(flat_like[1], leaves)
        if shardings is not None:
            state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)
        return state
