"""Parameter lattice for the abstract kernel checker.

One :class:`LatticeConfig` is one point the checker proves the contracts
at: dtype x problem size x (tile, leaf) x engine x batch, plus the SSM
scan's own (seq, d_model, state, chunk, d_tile) axes.  Two lattices are
drawn from it:

* the **model lattice** (:func:`model_lattice`) — every combination the
  pure-arithmetic rules (block divisibility, prefetch bounds, VMEM
  budget) sweep; these cost microseconds each, so it is deliberately
  broad: non-divisible and non-pow2 sizes, both engines, every dtype;
* the **trace lattice** (:func:`trace_lattice`) — the subset actually
  pushed through ``jax.eval_shape`` (abstract tracing of the real
  wrappers, no device execution).  Tracing costs ~0.1-1 s per point, so
  this samples the interesting corners (smallest/largest tile, int and
  float keys, ragged + uniform, a non-divisible size) rather than the
  full cross product.

Sizes are chosen to exercise the historical failure modes: ``n = 96``
(smaller than every tile — the pure-JAX fallback route), ``n = 1000``
(non-pow2, non-divisible by any tile), ``n = 4096`` (clean pow2).
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Iterable, List, Tuple

DTYPES = ("float32", "int32", "bfloat16")
SIZES = (96, 1000, 4096)
TILES = (128, 512)
LEAVES = (8, 32)
ENGINES = ("hier", "matrix")
BATCHES = (1, 4)


@dataclass(frozen=True)
class LatticeConfig:
    """One point of the contract-checking sweep."""

    dtype: str = "float32"
    n: int = 4096  # total merged length / sorted row width
    batch: int = 4
    tile: int = 512
    leaf: int = 32
    engine: str = "hier"
    ragged: bool = False
    k: int = 8  # top-k width
    runs: int = 4  # merge_k fan-in
    # SSM-scan axes (kind="scan" ignores the merge axes above)
    seq: int = 256
    d_model: int = 128
    state: int = 8
    chunk: int = 64
    d_tile: int = 64

    def with_(self, **changes) -> "LatticeConfig":
        return replace(self, **changes)

    def describe(self) -> str:
        return (
            f"dtype={self.dtype} n={self.n} batch={self.batch} tile={self.tile} "
            f"leaf={self.leaf} engine={self.engine} ragged={self.ragged}"
        )


def model_lattice() -> List[LatticeConfig]:
    """Full cross product for the arithmetic rules (~hundreds of points)."""
    out = []
    for dtype in DTYPES:
        for n in SIZES:
            for tile in TILES:
                for leaf in LEAVES:
                    for engine in ENGINES:
                        for batch in BATCHES:
                            out.append(
                                LatticeConfig(
                                    dtype=dtype, n=n, batch=batch,
                                    tile=tile, leaf=leaf, engine=engine,
                                )
                            )
    return out


def trace_lattice(fast: bool = False) -> List[LatticeConfig]:
    """Sampled corners for abstract tracing (eval_shape) of the wrappers.

    ``fast=True`` (the test suite) keeps two points per contract family;
    the full set (``make check``) adds the int-key, big-tile, matrix-
    engine and non-divisible corners.
    """
    pts = [
        LatticeConfig(dtype="float32", n=1000, tile=128, leaf=8, engine="hier"),
        LatticeConfig(dtype="int32", n=4096, tile=512, leaf=32, engine="hier"),
    ]
    if not fast:
        pts += [
            LatticeConfig(dtype="float32", n=4096, tile=512, leaf=8, engine="matrix"),
            LatticeConfig(dtype="bfloat16", n=1000, tile=128, leaf=32, engine="hier"),
            LatticeConfig(dtype="int32", n=96, tile=128, leaf=8, engine="hier"),
        ]
    return pts


def scan_lattice(fast: bool = False) -> List[LatticeConfig]:
    """SSM-scan configs: chunk-divisible and chunk-straddling seq lengths."""
    pts = [
        LatticeConfig(dtype="float32", batch=2, seq=256, d_model=128, state=8,
                      chunk=64, d_tile=64),
    ]
    if not fast:
        pts += [
            # chunk does not divide seq (the identity-step padded tail) and
            # d_tile does not divide d_model (wrapper shrinks it to a divisor)
            LatticeConfig(dtype="bfloat16", batch=1, seq=200, d_model=96, state=4,
                          chunk=64, d_tile=64),
        ]
    return pts
