"""Engine 1 of the static checker: abstract kernel-contract analysis.

For every contract in :mod:`repro.analysis.registry` this module proves,
**without launching a single kernel**, that the invariants hold across
the parameter lattice:

* **A000 registry completeness** — every public entry point of
  ``kernels/ops.py`` (plus ``ssm_scan_pallas``) carries a contract; an
  un-annotated kernel is an unchecked kernel.
* **A001 shape contract** — ``jax.eval_shape`` of the real wrapper (pure
  abstract tracing) must produce exactly the output shapes/dtypes the
  contract's model predicts, on every lattice point.
* **A002 block divisibility** — output BlockSpecs tile the padded
  extents exactly (Cor. 7's equal output partition), sort tiles are
  powers of two with ``tile | 2 * width`` for every wide round, and a
  wrapper given a non-pow2 sort tile must *reject* it loudly rather
  than silently running a different tile.
* **A003 prefetch bounds** — the scalar-prefetched window starts,
  bounded analytically from Algorithm 2's search interval
  (``lo >= max(0, d - |B|)``, ``hi <= min(d, |A|)``), can never slice
  past the sentinel-padded buffer ends.
* **A004 sentinel policy** — any contract that carries values or ragged
  lengths must use PR 2's masked (pads-excluded-by-index) rank form;
  unmasked keys-only contracts must state their tie-then-stability
  justification.  This is exactly the class of bug where a window pad
  tied with a real ``+inf`` / ``iinfo.max`` key leaked a zero value.
* **A005 VMEM budget** — a closed-form model of each kernel's per-grid-
  step VMEM high-water (window blocks + engine working set, or the SSM
  backward's ``(chunk+1) * d_tile * st`` recompute slab) must fit the
  per-device budget table.
* **A006 gradient shapes** — for ``differentiable`` contracts,
  ``jax.grad`` is traced abstractly through the ``custom_vjp`` (this
  traces the backward Pallas kernel too) and every cotangent must come
  back with its primal's shape and dtype.

Every check is an ordinary function taking explicit parameters, so the
test suite can aim them at known-bad configurations (a VMEM-overflowing
tile, a padding model with the sentinel tail removed) and assert the
rules actually fire.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .lattice import LatticeConfig, model_lattice, scan_lattice, trace_lattice
from .registry import REGISTRY, KernelContract, registered_contracts

# Per-device VMEM budgets (bytes).  ~16 MiB/core across current TPU
# generations (see the Pallas TPU guide's memory-hierarchy table); the
# model must fit with headroom for double buffering, so the checker
# budgets a USABLE fraction of the physical size.
VMEM_BUDGET_BYTES: Dict[str, int] = {
    "tpu-v3": 16 * 2**20,
    "tpu-v4": 16 * 2**20,
    "tpu-v5e": 16 * 2**20,
    "tpu-v5p": 16 * 2**20,
}
VMEM_USABLE_FRACTION = 0.9

VALUE_DTYPE = "float32"  # payload dtype used by the abstract sweeps


@dataclass(frozen=True)
class Violation:
    """One contract violation found by the checker."""

    rule: str  # "A000".."A006"
    kernel: str
    config: str
    message: str

    def __str__(self) -> str:
        return f"[{self.rule}] {self.kernel} ({self.config}): {self.message}"


def _dt(name: str):
    import jax.numpy as jnp

    return jnp.dtype(name)


def _esize(name: str) -> int:
    return _dt(name).itemsize


def _is_float(name: str) -> bool:
    import jax.numpy as jnp

    return jnp.issubdtype(_dt(name), jnp.inexact)


def _split(n: int) -> Tuple[int, int]:
    """Uneven |A|, |B| split (na != nb exercises the clamped diagonals)."""
    na = max(1, (2 * n) // 3)
    return na, n - na


def _pow2_ceil(n: int) -> int:
    return 1 << max(0, (max(1, n) - 1).bit_length())


# ---------------------------------------------------------------------------
# Abstract call builders: contract name -> (callable, arg specs, expected
# outputs, differentiable argnums).  These are the checker's model of each
# entry point's *signature*; A001 compares them against reality.
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    import jax

    return jax.ShapeDtypeStruct(tuple(shape), _dt(dtype))


def _build(contract: KernelContract, cfg: LatticeConfig):
    """Return ``(fn, args, expected_outputs, diff_argnums)`` for one config.

    ``expected_outputs`` is a list of ``(shape, dtype_name)``;
    ``diff_argnums`` indexes the float-differentiable arguments (empty
    for non-differentiable contracts or int-key configs).
    """
    import repro.kernels.ops as ops
    from repro.kernels import ssm_scan as scan_mod

    name, dt, vd = contract.name, cfg.dtype, VALUE_DTYPE
    kw = dict(tile=cfg.tile, leaf=cfg.leaf, engine=cfg.engine)
    n, bsz, k = cfg.n, cfg.batch, min(cfg.k, cfg.n)
    na, nb = _split(n)
    lens32 = _sds((bsz,), "int32")

    if name == "merge":
        return (lambda a, b: ops.merge(a, b, **kw),
                [_sds((na,), dt), _sds((nb,), dt)], [((n,), dt)], ())
    if name == "merge_kv":
        return (lambda ak, av, bk, bv: ops.merge_kv(ak, av, bk, bv, **kw),
                [_sds((na,), dt), _sds((na,), vd), _sds((nb,), dt), _sds((nb,), vd)],
                [((n,), dt), ((n,), vd)], ())
    if name == "merge_batched":
        return (lambda a, b: ops.merge_batched(a, b, **kw),
                [_sds((bsz, na), dt), _sds((bsz, nb), dt)], [((bsz, n), dt)], ())
    if name == "merge_kv_batched":
        return (lambda ak, av, bk, bv: ops.merge_kv_batched(ak, av, bk, bv, **kw),
                [_sds((bsz, na), dt), _sds((bsz, na), vd),
                 _sds((bsz, nb), dt), _sds((bsz, nb), vd)],
                [((bsz, n), dt), ((bsz, n), vd)], ())
    if name == "merge_batched_ragged":
        return (lambda a, b, la, lb: ops.merge_batched_ragged(a, b, la, lb, **kw),
                [_sds((bsz, na), dt), _sds((bsz, nb), dt), lens32, lens32],
                [((bsz, n), dt)], ())
    if name == "merge_kv_batched_ragged":
        return (lambda ak, av, bk, bv, la, lb:
                ops.merge_kv_batched_ragged(ak, av, bk, bv, la, lb, **kw),
                [_sds((bsz, na), dt), _sds((bsz, na), vd),
                 _sds((bsz, nb), dt), _sds((bsz, nb), vd), lens32, lens32],
                [((bsz, n), dt), ((bsz, n), vd)], ())
    if name == "sort":
        return (lambda x: ops.sort(x, **kw), [_sds((n,), dt)], [((n,), dt)],
                (0,) if _is_float(dt) else ())
    if name == "sort_kv":
        return (lambda ks, vs: ops.sort_kv(ks, vs, **kw),
                [_sds((n,), dt), _sds((n,), vd)], [((n,), dt), ((n,), vd)],
                (0, 1) if _is_float(dt) else (1,))
    if name == "sort_batched":
        return (lambda x: ops.sort_batched(x, **kw),
                [_sds((bsz, n), dt)], [((bsz, n), dt)],
                (0,) if _is_float(dt) else ())
    if name == "sort_kv_batched":
        return (lambda ks, vs: ops.sort_kv_batched(ks, vs, **kw),
                [_sds((bsz, n), dt), _sds((bsz, n), vd)],
                [((bsz, n), dt), ((bsz, n), vd)],
                (0, 1) if _is_float(dt) else (1,))
    if name == "merge_k":
        n_run = max(1, n // cfg.runs)
        return (lambda runs, lens: ops.merge_k(runs, lens, **kw),
                [_sds((cfg.runs, n_run), dt), _sds((cfg.runs,), "int32")],
                [((cfg.runs * n_run,), dt)], ())
    if name == "topk_batched":
        return (lambda x: ops.topk_batched(x, k, **kw),
                [_sds((bsz, n), dt)], [((bsz, k), dt), ((bsz, k), "int32")],
                (0,) if _is_float(dt) else ())
    if name == "topk_batched_ragged":
        return (lambda x, ln: ops.topk_batched_ragged(x, k, ln, **kw),
                [_sds((bsz, n), dt), lens32],
                [((bsz, k), dt), ((bsz, k), "int32")],
                (0,) if _is_float(dt) else ())
    if name == "ssm_scan_pallas":
        b, s, d, st = cfg.batch, cfg.seq, cfg.d_model, cfg.state
        return (lambda dtt, x, bm, cm, a: scan_mod.ssm_scan_pallas(
                    dtt, x, bm, cm, a, chunk=cfg.chunk, d_tile=cfg.d_tile),
                [_sds((b, s, d), dt), _sds((b, s, d), dt),
                 _sds((b, s, st), dt), _sds((b, s, st), dt), _sds((d, st), dt)],
                [((b, s, d), dt), ((b, d, st), "float32")],
                (0, 1, 2, 3, 4) if _is_float(dt) else ())
    raise KeyError(f"no abstract builder for contract {name!r} — add one "
                   f"to repro.analysis.checker._build")


# ---------------------------------------------------------------------------
# A001: eval_shape vs the contract's signature model
# ---------------------------------------------------------------------------


def shape_violations(contract: KernelContract, cfg: LatticeConfig) -> List[Violation]:
    import jax

    fn, args, expected, _ = _build(contract, cfg)
    try:
        out = jax.eval_shape(fn, *args)
    except Exception as e:  # tracing itself must not fail on a valid config
        return [Violation("A001", contract.name, cfg.describe(),
                          f"abstract trace failed: {type(e).__name__}: {e}")]
    outs = list(out) if isinstance(out, (tuple, list)) else [out]
    if len(outs) != len(expected):
        return [Violation("A001", contract.name, cfg.describe(),
                          f"expected {len(expected)} outputs, traced {len(outs)}")]
    vs = []
    for i, (o, (shape, dtype)) in enumerate(zip(outs, expected)):
        if tuple(o.shape) != tuple(shape) or o.dtype != _dt(dtype):
            vs.append(Violation(
                "A001", contract.name, cfg.describe(),
                f"output {i}: traced {o.shape}/{o.dtype}, contract says "
                f"{tuple(shape)}/{dtype}"))
    return vs


# ---------------------------------------------------------------------------
# A002: block divisibility (+ loud rejection of non-pow2 sort tiles)
# ---------------------------------------------------------------------------


def _wide_widths(m: int, tile: int) -> List[int]:
    """Run widths handled by the flat kernel rounds for an m-element row."""
    w = 1
    while w < m and 2 * w <= tile:
        w *= 2
    out = []
    while w < m:
        out.append(w)
        w *= 2
    return out


def block_divisibility_violations(contract: KernelContract, cfg: LatticeConfig) -> List[Violation]:
    vs = []
    name, tile, leaf = contract.name, cfg.tile, cfg.leaf
    if contract.kind == "scan":
        # the wrapper must normalize (chunk, d_tile) to divisors
        chunk = max(1, min(cfg.chunk, cfg.seq))
        if (-cfg.seq) % chunk and chunk > cfg.seq:
            vs.append(Violation("A002", name, cfg.describe(),
                                f"chunk {chunk} cannot pad seq {cfg.seq}"))
        d_tile = max(1, min(cfg.d_tile, cfg.d_model))
        while cfg.d_model % d_tile:
            d_tile -= 1
        if cfg.d_model % d_tile:
            vs.append(Violation("A002", name, cfg.describe(),
                                f"d_tile {d_tile} does not divide d_model {cfg.d_model}"))
        return vs
    if not 1 <= min(leaf, tile):
        vs.append(Violation("A002", name, cfg.describe(), f"leaf {leaf} unusable"))
    if contract.pow2_tile:
        if tile & (tile - 1):
            vs.append(Violation(
                "A002", name, cfg.describe(),
                f"sort tile {tile} is not a power of two (flat rounds need "
                f"tile | 2 * width with pow2 widths)"))
        else:
            m = _pow2_ceil(cfg.n)
            for w in _wide_widths(m, tile):
                if (2 * w) % tile:
                    vs.append(Violation(
                        "A002", name, cfg.describe(),
                        f"round width {w}: tile {tile} does not divide 2*width"))
    else:
        # merge kinds: the output BlockSpec must tile the padded extent
        nt = -(-cfg.n // tile)
        if (nt * tile) % tile:
            vs.append(Violation("A002", name, cfg.describe(),
                                "padded output extent not a multiple of the tile"))
    return vs


def rejection_violations(contract: KernelContract, bad_tile: int = 96) -> List[Violation]:
    """pow2_tile contracts must REJECT a non-pow2 tile, not run it."""
    import jax

    if not contract.pow2_tile:
        return []
    cfg = LatticeConfig(n=4096, tile=bad_tile, leaf=8)
    fn, args, _, _ = _build(contract, cfg)
    try:
        jax.eval_shape(fn, *args)
    except ValueError:
        return []  # loud rejection — exactly what the contract demands
    except Exception as e:
        return [Violation("A002", contract.name, cfg.describe(),
                          f"non-pow2 tile {bad_tile} raised {type(e).__name__} "
                          f"instead of ValueError")]
    return [Violation("A002", contract.name, cfg.describe(),
                      f"non-pow2 tile {bad_tile} was silently accepted")]


# ---------------------------------------------------------------------------
# A003: scalar-prefetch window starts stay inside the padded buffers
# ---------------------------------------------------------------------------


def prefetch_violations(
    contract: KernelContract,
    cfg: LatticeConfig,
    pad_elems: Optional[int] = None,
) -> List[Violation]:
    """Bound the prefetched starts analytically and check every windowed
    read ``[start, start + tile)`` lands inside the padded buffer.

    ``pad_elems`` overrides the modeled sentinel padding (the `_prepare`
    family appends ``tile`` sentinels); the tests pass ``0`` to model a
    kernel that forgot its padding and assert this rule fires.
    """
    tile = cfg.tile
    pad = tile if pad_elems is None else pad_elems
    name = contract.name
    vs = []
    if contract.kind in ("merge", "merge_k"):
        if contract.kind == "merge_k":
            # tournament rounds run the ragged batched kernel on (k/2, n_run*2)
            na = nb = max(1, cfg.n // cfg.runs)
            n = na + nb
        else:
            na, nb = _split(cfg.n)
            n = cfg.n
        nt = -(-n // tile)
        # Alg. 2 invariant: a_start in [max(0, d - nb), min(d, na)]
        max_diag = min((nt - 1) * tile, n)
        max_a = min(max_diag, na)
        max_b = min(max_diag, nb)
        if max_a + tile > na + pad:
            vs.append(Violation(
                "A003", name, cfg.describe(),
                f"A window read can reach {max_a + tile} but the padded "
                f"buffer holds {na + pad} elements"))
        if max_b + tile > nb + pad:
            vs.append(Violation(
                "A003", name, cfg.describe(),
                f"B window read can reach {max_b + tile} but the padded "
                f"buffer holds {nb + pad} elements"))
    elif contract.kind in ("sort", "topk"):
        if cfg.tile & (cfg.tile - 1):
            return vs  # rejected configs never reach the prefetch tables
        m_row = _pow2_ceil(cfg.n)
        m = m_row * (cfg.batch if contract.batched else 1)
        for w in _wide_widths(m_row, tile):
            npairs = m // (2 * w)
            tpp = (2 * w) // tile
            max_d = (tpp - 1) * tile
            base = (npairs - 1) * 2 * w
            max_fa = base + min(max_d, w)
            max_fb = base + w + min(max_d, w)
            hi = max(max_fa, max_fb)
            if hi + tile > m + pad:
                vs.append(Violation(
                    "A003", name, cfg.describe(),
                    f"round width {w}: flat window read can reach "
                    f"{hi + tile} but the buffer holds {m + pad} elements"))
    return vs


# ---------------------------------------------------------------------------
# A004: sentinel / masked-rank policy
# ---------------------------------------------------------------------------


def sentinel_violations(contract: KernelContract) -> List[Violation]:
    if contract.kind == "scan":
        return []  # no rank path, no sentinels
    vs = []
    if (contract.carries_values or contract.ragged) and not contract.masked_ranks:
        what = "values" if contract.carries_values else "ragged lengths"
        vs.append(Violation(
            "A004", contract.name, "-",
            f"carries {what} on an UNMASKED rank path: a window pad tied "
            f"with a real +inf / iinfo.max key can steal its slot and leak "
            f"a zero value (PR 2's sentinel-collision bug class)"))
    if not contract.masked_ranks and not contract.tie_safe:
        vs.append(Violation(
            "A004", contract.name, "-",
            "unmasked rank path without a tie_safe justification — state "
            "why sentinel-tied real keys still merge bit-exactly"))
    return vs


# ---------------------------------------------------------------------------
# A005: modeled VMEM high-water vs the device budget table
# ---------------------------------------------------------------------------


def vmem_bytes(contract: KernelContract, cfg: LatticeConfig) -> int:
    """Closed-form per-grid-step VMEM high-water model (bytes).

    Tile kernels: two input windows + output block(s) plus the engine
    working set — the ``(T, T)`` merge matrix and one-hot select for the
    matrix engine (its defining cost), the ``(L, S, S)`` leaf stack and
    O(T) gather temporaries for the hierarchical engine.  SSM scan: the
    BlockSpec'd operands plus the scratch slabs, with the backward's
    ``(chunk+1) * d_tile * st`` recompute buffer dominating (the formula
    next to ``ssm_scan.bwd_hbm_bytes``).
    """
    e = _esize(cfg.dtype)
    if contract.kind == "scan":
        b, s, d, st = 1, cfg.chunk, cfg.d_tile, cfg.state  # one grid step
        f32 = 4
        fwd = (3 * s * d * e          # dt, x, y blocks
               + 2 * s * st * e       # B, C blocks
               + d * st * e           # A block
               + 2 * d * st * f32)    # h scratch + checkpoint block
        if not contract.differentiable:
            return fwd
        n_d = max(1, cfg.d_model // max(1, cfg.d_tile))
        bwd = (3 * s * d * e                 # dt, x blocks + dy
               + 2 * s * st * e + d * st * e  # B, C, A blocks
               + 2 * d * st * f32            # hstart + dhfin blocks
               + 2 * s * d * f32             # ddt, dx out blocks
               + 2 * s * st * f32 + d * st * f32  # dB, dC, dA out blocks
               + (s + 1) * d * st * f32      # recomputed chunk states
               + 2 * n_d * d * st * f32)     # g carry + dA accumulator slabs
        return max(fwd, bwd)
    tile, leaf = cfg.tile, max(1, min(cfg.leaf, cfg.tile))
    v = _esize(VALUE_DTYPE) if contract.carries_values else 0
    io = 3 * tile * (e + v)  # two input windows + one output block (per operand)
    i32 = 4
    if cfg.engine == "matrix":
        # (T, T) bool merge matrix + the (T, T) one-hot select staged per
        # operand dtype (the widest where() intermediate dominates)
        work = tile * tile * (1 + max(e, v)) + 2 * tile * i32
    else:
        nleaf = -(-tile // leaf)
        # (L, S, S) bool leaf matrices + int32 rank sums, then O(T) ranks,
        # alpha counts and gather indices
        work = nleaf * leaf * leaf * (1 + i32) + 6 * tile * i32 + 2 * (tile + leaf) * (e + v)
    return io + work


def vmem_violations(
    contract: KernelContract,
    cfg: LatticeConfig,
    budgets: Optional[Dict[str, int]] = None,
    usable_fraction: float = VMEM_USABLE_FRACTION,
) -> List[Violation]:
    budgets = VMEM_BUDGET_BYTES if budgets is None else budgets
    hw = vmem_bytes(contract, cfg)
    vs = []
    for dev, cap in sorted(budgets.items()):
        limit = int(cap * usable_fraction)
        if hw > limit:
            vs.append(Violation(
                "A005", contract.name, cfg.describe(),
                f"modeled VMEM high-water {hw} B exceeds the {dev} budget "
                f"{limit} B ({usable_fraction:.0%} of {cap} B)"))
    return vs


# ---------------------------------------------------------------------------
# A006: abstract gradient tracing for differentiable contracts
# ---------------------------------------------------------------------------


def grad_violations(contract: KernelContract, cfg: LatticeConfig) -> List[Violation]:
    import jax
    import jax.numpy as jnp

    if not contract.differentiable:
        return []
    fn, args, _, argnums = _build(contract, cfg)
    if not argnums:
        return []

    def scalar_loss(*xs):
        outs = fn(*xs)
        outs = outs if isinstance(outs, (tuple, list)) else (outs,)
        tot = jnp.zeros((), jnp.float32)
        for o in outs:
            if jnp.issubdtype(o.dtype, jnp.inexact):
                tot = tot + jnp.sum(o.astype(jnp.float32))
        return tot

    try:
        grads = jax.eval_shape(jax.grad(scalar_loss, argnums=argnums), *args)
    except Exception as e:
        return [Violation("A006", contract.name, cfg.describe(),
                          f"abstract backward trace failed: {type(e).__name__}: {e}")]
    vs = []
    for i, g in zip(argnums, grads if isinstance(grads, (tuple, list)) else [grads]):
        a = args[i]
        if tuple(g.shape) != tuple(a.shape) or g.dtype != a.dtype:
            vs.append(Violation(
                "A006", contract.name, cfg.describe(),
                f"cotangent of arg {i}: {g.shape}/{g.dtype} != primal "
                f"{a.shape}/{a.dtype}"))
    return vs


# ---------------------------------------------------------------------------
# A000 + the driver
# ---------------------------------------------------------------------------


def completeness_violations(contracts: Optional[Dict[str, KernelContract]] = None) -> List[Violation]:
    """Every public ``kernels.ops`` callable (and the SSM scan entry
    point) must be registered — an un-annotated kernel is unchecked."""
    import repro.kernels.ops as ops

    contracts = registered_contracts() if contracts is None else contracts
    vs = []
    for name, obj in sorted(vars(ops).items()):
        if name.startswith("_") or not callable(obj) or isinstance(obj, type):
            continue
        if getattr(obj, "__module__", None) != "repro.kernels.ops":
            continue
        if name not in contracts:
            vs.append(Violation(
                "A000", name, "-",
                "public kernels.ops entry point has no kernel_contract "
                "annotation — add one (see docs/analysis.md)"))
    if "ssm_scan_pallas" not in contracts:
        vs.append(Violation("A000", "ssm_scan_pallas", "-",
                            "the fused SSM scan has no kernel_contract annotation"))
    return vs


def _configs_for(contract: KernelContract, fast: bool, trace: bool) -> List[LatticeConfig]:
    if contract.kind == "scan":
        return scan_lattice(fast)
    return trace_lattice(fast) if trace else model_lattice()


def check_contract(
    contract: KernelContract,
    *,
    fast: bool = False,
    budgets: Optional[Dict[str, int]] = None,
    trace: bool = True,
) -> List[Violation]:
    """All rules for one contract over its applicable lattice slices."""
    vs = sentinel_violations(contract)
    for cfg in _configs_for(contract, fast, trace=False):
        vs += block_divisibility_violations(contract, cfg)
        vs += prefetch_violations(contract, cfg)
        vs += vmem_violations(contract, cfg, budgets)
    if trace:
        vs += rejection_violations(contract)
        trace_cfgs = _configs_for(contract, fast, trace=True)
        for cfg in trace_cfgs:
            vs += shape_violations(contract, cfg)
        # one backward trace per contract is enough to prove the VJP
        # machinery composes abstractly — pick the first float config
        for cfg in trace_cfgs:
            if _is_float(cfg.dtype):
                vs += grad_violations(contract, cfg)
                break
    return vs


def check_kernels(
    *,
    fast: bool = False,
    budgets: Optional[Dict[str, int]] = None,
    trace: bool = True,
) -> List[Violation]:
    """Run the full abstract analysis: registry completeness plus every
    rule on every registered contract.  ``fast=True`` shrinks the trace
    lattice (the arithmetic rules always sweep the full model lattice).
    No kernel is ever launched — everything goes through ``eval_shape``.
    """
    # importing the kernel modules populates the registry
    import repro.kernels.ops  # noqa: F401
    import repro.kernels.ssm_scan  # noqa: F401

    contracts = registered_contracts()
    vs = completeness_violations(contracts)
    for name in sorted(contracts):
        vs += check_contract(contracts[name], fast=fast, budgets=budgets, trace=trace)
    return vs
