"""Static analysis for the Merge Path kernels: prove the contracts
before anything runs.

Two engines (see ``docs/analysis.md`` for the rule catalog):

* **abstract kernel analysis** (:mod:`repro.analysis.checker`) — every
  kernel entry point declares a :class:`KernelContract`; the checker
  sweeps a parameter lattice with ``jax.eval_shape`` (no device
  execution) and closed-form models of block divisibility, scalar-
  prefetch bounds, sentinel policy and VMEM high-water;
* **AST lint** (``tools/lint_rules.py``) — repo-specific source rules
  learned from past bugs (literal ``interpret=``, ``-x`` on int keys,
  raw sentinel construction, loop-over-pairs hot paths, untested
  ``custom_vjp``).

Entry points: ``python -m repro.analysis [--fast]`` or ``make check``.
"""

from .checker import (
    VMEM_BUDGET_BYTES,
    Violation,
    block_divisibility_violations,
    check_contract,
    check_kernels,
    completeness_violations,
    grad_violations,
    prefetch_violations,
    rejection_violations,
    sentinel_violations,
    shape_violations,
    vmem_bytes,
    vmem_violations,
)
from .lattice import LatticeConfig, model_lattice, scan_lattice, trace_lattice
from .registry import REGISTRY, KernelContract, kernel_contract, registered_contracts

__all__ = [
    "KernelContract",
    "kernel_contract",
    "registered_contracts",
    "REGISTRY",
    "LatticeConfig",
    "model_lattice",
    "trace_lattice",
    "scan_lattice",
    "Violation",
    "VMEM_BUDGET_BYTES",
    "check_kernels",
    "check_contract",
    "completeness_violations",
    "shape_violations",
    "block_divisibility_violations",
    "rejection_violations",
    "prefetch_violations",
    "sentinel_violations",
    "vmem_bytes",
    "vmem_violations",
    "grad_violations",
]
