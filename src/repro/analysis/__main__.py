"""CLI driver: ``python -m repro.analysis [--fast]``.

Runs the abstract kernel analysis (Engine 1) over every registered
contract and exits non-zero if any rule fires.  Pure abstract tracing —
no kernel is launched, so this is safe (and fast) on a CPU-only CI box.
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Abstract contract checker for the Merge Path kernels.",
    )
    ap.add_argument("--fast", action="store_true",
                    help="shrink the eval_shape trace lattice (test-suite mode)")
    ap.add_argument("--no-trace", action="store_true",
                    help="arithmetic rules only — skip eval_shape tracing")
    args = ap.parse_args(argv)

    from . import check_kernels, registered_contracts

    t0 = time.time()
    violations = check_kernels(fast=args.fast, trace=not args.no_trace)
    dt = time.time() - t0
    n = len(registered_contracts())
    if violations:
        for v in violations:
            print(f"analysis: {v}", file=sys.stderr)
        print(f"analysis: FAIL ({len(violations)} violations across "
              f"{n} contracts, {dt:.1f}s)", file=sys.stderr)
        return 1
    print(f"analysis: OK ({n} contracts proven on the lattice, {dt:.1f}s, "
          f"0 kernels launched)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
