"""Kernel contract registry — the declarative half of the static checker.

Every kernel entry point in :mod:`repro.kernels.ops` and
:mod:`repro.kernels.ssm_scan` carries a :func:`kernel_contract` annotation
stating the invariants the Merge Path paper (and six PRs of bug history)
guarantee for it:

* **kind** — which abstract model the checker applies: the tiled merge
  kernels share one grid/BlockSpec/prefetch model, the flat sort rounds
  another, the SSM scan its own (see ``repro.analysis.checker``);
* **masked_ranks** — whether window pads are excluded from cross-ranks by
  *index* (PR 2's rule: a pad tied with a real ``+inf`` / ``iinfo.max``
  key must never steal its slot and surface a zero value).  Contracts
  that carry values or ragged lengths MUST be masked; keys-only contracts
  may use the cheaper unmasked form but then MUST state the
  tie-then-stability justification in ``tie_safe``;
* **pow2_tile** — the flat sort rounds require ``tile | 2 * width`` with
  power-of-two widths, so the wrapper must *reject* a non-pow2 tile
  loudly (the checker verifies the rejection actually happens);
* **differentiable** — the wrapper defines a ``custom_vjp``; the checker
  then also traces the backward abstractly and the AST lint (L005)
  demands a registered gradient test.

This module is deliberately dependency-free (no jax import): the
annotations live on the hot dispatch surface (``kernels/ops.py``) and
must cost nothing at import time.  All heavy lifting — abstract tracing,
VMEM/prefetch models, the parameter lattice — lives in
:mod:`repro.analysis.checker`, keyed by the facts declared here.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Dict, Optional, Tuple

# Abstract models the checker knows how to apply.  "merge" covers the
# tiled 1-D / batched / ragged merge kernels (scalar-prefetched start
# tables, one (tile,) output block per grid step); "sort" the flat
# bottom-up rounds (pow2 widths over a (m + tile,) buffer); "topk" the
# flip-then-kv-sort reduction; "merge_k" the tournament over the ragged
# batched kernel; "scan" the fused SSM scan.
KINDS = ("merge", "sort", "topk", "merge_k", "scan")


@dataclass(frozen=True)
class KernelContract:
    """Declared invariants of one kernel entry point (see module doc)."""

    name: str
    kind: str
    fn: Any = field(default=None, repr=False, compare=False)
    batched: bool = False
    ragged: bool = False
    carries_values: bool = False
    masked_ranks: bool = False
    pow2_tile: bool = False
    differentiable: bool = False
    # justification for an unmasked rank path (keys-only contracts only):
    # why sentinel-tied real keys still merge bit-exactly
    tie_safe: Optional[str] = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown contract kind {self.kind!r} (expected one of {KINDS})")

    def with_(self, **changes) -> "KernelContract":
        """A modified copy — used by tests to build known-bad contracts."""
        return replace(self, **changes)


# name -> contract, populated at kernels import time by the decorator
REGISTRY: Dict[str, KernelContract] = {}


def kernel_contract(
    *,
    kind: str,
    name: Optional[str] = None,
    batched: bool = False,
    ragged: bool = False,
    carries_values: bool = False,
    masked_ranks: bool = False,
    pow2_tile: bool = False,
    differentiable: bool = False,
    tie_safe: Optional[str] = None,
):
    """Decorator: register the wrapped kernel entry point's contract.

    Returns the function unchanged (works above ``jax.jit`` wrappers —
    ``jit`` preserves ``__name__`` via ``functools.wraps``), so
    annotating a wrapper costs nothing at call time.
    """

    def deco(fn):
        cname = name or getattr(fn, "__name__", None)
        if not cname:
            raise ValueError("kernel_contract needs a name= for unnamed callables")
        REGISTRY[cname] = KernelContract(
            name=cname,
            kind=kind,
            fn=fn,
            batched=batched,
            ragged=ragged,
            carries_values=carries_values,
            masked_ranks=masked_ranks,
            pow2_tile=pow2_tile,
            differentiable=differentiable,
            tie_safe=tie_safe,
        )
        return fn

    return deco


def registered_contracts() -> Dict[str, KernelContract]:
    """Copy of the registry (import the kernel modules first — the
    registry is populated by their decorators)."""
    return dict(REGISTRY)
