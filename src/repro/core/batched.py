"""Batched & k-way Merge Path — the paper's partition, fused over a batch axis.

The paper's Segmented Parallel Merge is explicitly pitched as a building
block for "sorting and other functions" (§6).  This module generalizes the
pairwise 1-D primitives of :mod:`repro.core.merge_path` along the two axes
every real consumer needs:

* **Batched** (leading batch axis): ``merge_batched`` / ``merge_kv_batched``
  merge ``B`` independent pairs of sorted rows at once.  Instead of vmapping
  the scalar merge (which re-traces the bisection per lane), all ``B * n``
  diagonal binary searches run as *one* vectorized Algorithm 2 pass — the
  vector lanes play the role of the paper's cores across rows *and*
  diagonals simultaneously.  This is the form the Pallas kernel's 2-D
  ``(batch, tile)`` grid consumes (``repro.kernels.merge_path``).
* **k-way**: ``merge_k`` / ``merge_k_kv`` merge ``k`` sorted runs by a
  tournament of pairwise Merge Paths (``ceil(log2 k)`` batched rounds), the
  classic multiway generalization of the co-rank partition (cf. Träff,
  "Simplified, stable parallel merging", PAPERS.md).  ``merge_sort_k`` is
  the bottom-up sort whose outer rounds instead merge each group of ``k``
  runs in a *single* multiway co-rank pass, rewriting the data only
  ``ceil(log_k N)`` times; with ``k = 2`` it is exactly the paper's merge
  sort.

Conventions match :mod:`repro.core.merge_path`: rows sorted ascending,
merges stable with A-priority (ties take A first; original order kept
within each input).  Sentinel padding (``max_sentinel``) is used for
power-of-two round structure, so payloads must be strictly below the
dtype's maximum — the same caveat as ``merge_sort``.

Everything is jittable and shardable; no Python-level per-row loops.
"""

from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from .merge_path import max_sentinel

__all__ = [
    "searchsorted_batched",
    "diagonal_intersections_batched",
    "merge_batched",
    "merge_kv_batched",
    "merge_sort_batched",
    "merge_sort_kv_batched",
    "stable_argsort_batched",
    "topk_batched",
    "merge_k",
    "merge_k_kv",
    "merge_sort_k",
]


def _bisect_steps(n: int) -> int:
    """Fixed trip count for a bisection over an interval of length ``n + 1``."""
    return max(1, int(math.ceil(math.log2(n + 1))) + 1)


def searchsorted_batched(sorted_rows: jax.Array, queries: jax.Array, side: str = "left") -> jax.Array:
    """Row-wise ``searchsorted``: one fused bisection over the whole batch.

    ``sorted_rows`` is ``(B, n)`` with each row ascending; ``queries`` is
    ``(B, m)``.  Returns ``(B, m)`` int32 insertion points, equal to
    ``jnp.searchsorted(sorted_rows[i], queries[i], side)`` per row.

    This is the cross-diagonal binary search of Algorithm 2 in its rank
    reading: with ``side="left"`` the result is ``|{j : row[j] < q}|``,
    with ``side="right"`` it is ``|{j : row[j] <= q}|`` — the two tie
    orientations that make the pairwise merge stable with A-priority.
    """
    if side not in ("left", "right"):
        raise ValueError(f"side must be 'left' or 'right', got {side!r}")
    b, n = sorted_rows.shape
    if n == 0:
        return jnp.zeros(queries.shape, jnp.int32)
    lo = jnp.zeros(queries.shape, jnp.int32)
    hi = jnp.full(queries.shape, n, jnp.int32)

    def body(_, lh):
        lo, hi = lh
        mid = (lo + hi) >> 1
        sv = jnp.take_along_axis(sorted_rows, jnp.clip(mid, 0, n - 1), axis=1)
        go_right = (sv < queries) if side == "left" else (sv <= queries)
        active = lo < hi
        lo2 = jnp.where(active & go_right, mid + 1, lo)
        hi2 = jnp.where(active & ~go_right, mid, hi)
        return lo2, hi2

    lo, hi = jax.lax.fori_loop(0, _bisect_steps(n), body, (lo, hi))
    return lo


def diagonal_intersections_batched(a: jax.Array, b: jax.Array, diags: jax.Array) -> jax.Array:
    """Algorithm 2, vectorized over rows *and* diagonals at once.

    ``a`` is ``(B, na)``, ``b`` is ``(B, nb)``, ``diags`` is ``(D,)`` or
    ``(B, D)`` with ints in ``[0, na + nb]``.  Returns ``ai`` of shape
    ``(B, D)``: for batch row ``r`` and diagonal ``d``, the first ``d``
    outputs of the stable merge of ``a[r]`` and ``b[r]`` are
    ``a[r, :ai]`` and ``b[r, :d - ai]``.

    Equivalent to ``vmap(diagonal_intersections)`` but with a single
    fused bisection — one trip count, one gather per step, every
    ``(row, diagonal)`` pair in its own vector lane.
    """
    bsz, na = a.shape
    nb = b.shape[1]
    diags = jnp.asarray(diags, jnp.int32)
    if diags.ndim == 1:
        diags = jnp.broadcast_to(diags[None, :], (bsz, diags.shape[0]))
    if nb == 0:  # path is a straight vertical line
        return jnp.minimum(diags, na)
    if na == 0:  # straight horizontal line
        return jnp.zeros_like(diags)
    lo = jnp.maximum(0, diags - nb)
    hi = jnp.minimum(diags, na)

    def body(_, lh):
        lo, hi = lh
        mid = (lo + hi) >> 1
        av = jnp.take_along_axis(a, jnp.clip(mid, 0, na - 1), axis=1)
        bv = jnp.take_along_axis(b, jnp.clip(diags - 1 - mid, 0, nb - 1), axis=1)
        pred = av <= bv  # A-priority: A[i] precedes B[j] iff A[i] <= B[j]
        active = lo < hi
        lo2 = jnp.where(active & pred, mid + 1, lo)
        hi2 = jnp.where(active & ~pred, mid, hi)
        return lo2, hi2

    lo, hi = jax.lax.fori_loop(0, _bisect_steps(min(na, nb)), body, (lo, hi))
    return lo


def _batched_ranks(a: jax.Array, b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Cross-ranks of every element of every row pair, in one fused pass."""
    na, nb = a.shape[1], b.shape[1]
    ia = jnp.arange(na, dtype=jnp.int32)[None, :] + searchsorted_batched(b, a, side="left")
    ib = jnp.arange(nb, dtype=jnp.int32)[None, :] + searchsorted_batched(a, b, side="right")
    return ia, ib


def merge_batched(a: jax.Array, b: jax.Array) -> jax.Array:
    """Stable merge of ``B`` pairs of sorted rows: ``(B, na) + (B, nb) -> (B, na + nb)``.

    Row ``r`` of the result is exactly ``merge(a[r], b[r])`` (stable,
    A-priority) — bit-identical to the vmapped pairwise merge, but computed
    by a single vectorized Algorithm 2 pass: every element's output
    position is its cross-rank, and all ``B * (na + nb)`` rank searches
    share one fixed-trip bisection.
    """
    bsz, na = a.shape
    nb = b.shape[1]
    dtype = jnp.result_type(a, b)
    ia, ib = _batched_ranks(a, b)
    rows = jnp.arange(bsz, dtype=jnp.int32)[:, None]
    out = jnp.zeros((bsz, na + nb), dtype)
    out = out.at[rows, ia].set(a.astype(dtype))
    out = out.at[rows, ib].set(b.astype(dtype))
    return out


def merge_kv_batched(
    ak: jax.Array, av: jax.Array, bk: jax.Array, bv: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Stable batched key-value merge; row ``r`` equals ``merge_kv`` of row ``r``.

    ``ak``/``bk`` are ``(B, na)``/``(B, nb)`` sorted key rows; ``av``/``bv``
    the same-shape value rows carried along the permutation.
    """
    bsz, na = ak.shape
    nb = bk.shape[1]
    kd = jnp.result_type(ak, bk)
    vd = jnp.result_type(av, bv)
    ia, ib = _batched_ranks(ak, bk)
    rows = jnp.arange(bsz, dtype=jnp.int32)[:, None]
    keys = jnp.zeros((bsz, na + nb), kd).at[rows, ia].set(ak.astype(kd)).at[rows, ib].set(bk.astype(kd))
    vals = jnp.zeros((bsz, na + nb), vd).at[rows, ia].set(av.astype(vd)).at[rows, ib].set(bv.astype(vd))
    return keys, vals


def _pad_rows_pow2(x: jax.Array, fill) -> jax.Array:
    """Pad the last axis of ``(B, n)`` to the next power of two with ``fill``."""
    n = x.shape[1]
    m = 1 << max(0, (n - 1).bit_length())
    if m == n:
        return x
    pad = jnp.full((x.shape[0], m - n), fill, x.dtype)
    return jnp.concatenate([x, pad], axis=1)


def merge_sort_batched(x: jax.Array) -> jax.Array:
    """Sort every row of ``(B, n)`` ascending via batched merge-path rounds.

    The classic bottom-up structure of the paper's merge sort, but each of
    the ``log2 n`` rounds merges *all* runs of *all* rows in one
    :func:`merge_batched` call — batch and pair axes are flattened
    together, so the vector utilization is independent of where we are in
    the round schedule.
    """
    bsz, n = x.shape
    if n <= 1:
        return x
    xp = _pad_rows_pow2(x, max_sentinel(x.dtype))
    m = xp.shape[1]
    width = 1
    while width < m:
        runs = xp.reshape(-1, 2, width)  # (B * m/2w, 2, w)
        xp = merge_batched(runs[:, 0], runs[:, 1]).reshape(bsz, m)
        width *= 2
    return xp[:, :n]


def merge_sort_kv_batched(keys: jax.Array, values: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Row-wise stable key-value sort of ``(B, n)`` keys (ascending).

    Stability is inherited from the A-priority pairwise merge, making this
    the batched form of the dispatch sort MoE relies on for deterministic
    capacity drops.
    """
    bsz, n = keys.shape
    if n <= 1:
        return keys, values
    kp = _pad_rows_pow2(keys, max_sentinel(keys.dtype))
    vp = _pad_rows_pow2(values, jnp.zeros((), values.dtype))
    m = kp.shape[1]
    width = 1
    while width < m:
        kr = kp.reshape(-1, 2, width)
        vr = vp.reshape(-1, 2, width)
        kp, vp = merge_kv_batched(kr[:, 0], vr[:, 0], kr[:, 1], vr[:, 1])
        kp = kp.reshape(bsz, m)
        vp = vp.reshape(bsz, m)
        width *= 2
    return kp[:, :n], vp[:, :n]


def stable_argsort_batched(keys: jax.Array) -> jax.Array:
    """Row-wise stable argsort (ascending) of ``(B, n)`` keys."""
    bsz, n = keys.shape
    idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (bsz, n))
    _, perm = merge_sort_kv_batched(keys, idx)
    return perm


def topk_batched(x: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Row-wise descending top-k of ``(B, n)``: ``(values, indices)``, each ``(B, k)``.

    Stable like :func:`repro.core.merge_path.topk_desc` (among equal values
    the smallest index wins, matching ``jax.lax.top_k``), but all rows ride
    one batched kv-sort instead of a vmapped per-row sort.
    """
    perm = stable_argsort_batched(-x)
    top_idx = perm[:, :k]
    return jnp.take_along_axis(x, top_idx, axis=1), top_idx


# ---------------------------------------------------------------------------
# k-way tournament merges
# ---------------------------------------------------------------------------

def _stack_runs(runs):
    """Normalize a ``(k, n)`` array or a sequence of sorted 1-D runs.

    Ragged runs are sentinel-padded to the longest; the total true length
    is returned so callers can trim the sentinels off the merged tail.
    """
    if isinstance(runs, jax.Array) or hasattr(runs, "shape"):
        runs = jnp.asarray(runs)
        if runs.ndim != 2:
            raise ValueError(f"expected (k, n) runs, got shape {runs.shape}")
        return runs, runs.shape[0] * runs.shape[1]
    runs = [jnp.asarray(r) for r in runs]
    if not runs:
        raise ValueError("merge_k needs at least one run")
    dtype = jnp.result_type(*runs)
    total = sum(r.shape[0] for r in runs)
    width = max(r.shape[0] for r in runs)
    sent = max_sentinel(dtype)
    padded = [
        jnp.concatenate([r.astype(dtype), jnp.full((width - r.shape[0],), sent, dtype)])
        for r in runs
    ]
    return jnp.stack(padded), total


def merge_k(runs) -> jax.Array:
    """Merge ``k`` sorted runs into one sorted array via a pairwise tournament.

    ``runs`` is a ``(k, n)`` array of sorted rows, or a sequence of sorted
    1-D arrays (possibly ragged — shorter runs are sentinel-padded).  The
    tournament runs ``ceil(log2 k)`` rounds; round ``j`` merges ``k / 2^j``
    run pairs with one :func:`merge_batched` call, i.e. the co-rank
    partition applied multiway exactly as in the stable multiway merges of
    Träff et al. (PAPERS.md).  ``k = 1`` is the identity.

    Stable across runs in input order: ties resolve toward the
    lower-indexed run (tournament rounds always merge lower-index runs as
    the A side).  Output length is the total number of true elements;
    sentinel padding is trimmed, which requires payloads strictly below
    ``max_sentinel(dtype)`` (the module-level caveat).
    """
    stacked, total = _stack_runs(runs)
    k = stacked.shape[0]
    target = 1 << max(0, (k - 1).bit_length())
    if target != k:
        pad = jnp.full((target - k, stacked.shape[1]), max_sentinel(stacked.dtype), stacked.dtype)
        stacked = jnp.concatenate([stacked, pad], axis=0)
    while stacked.shape[0] > 1:
        stacked = merge_batched(stacked[0::2], stacked[1::2])
    return stacked[0][:total]


def merge_k_kv(key_runs, value_runs) -> Tuple[jax.Array, jax.Array]:
    """Key-value :func:`merge_k`: merge ``k`` sorted (keys, values) runs.

    ``key_runs`` / ``value_runs`` are matching ``(k, n)`` arrays or
    sequences of matching 1-D runs.  Stable with lower-run priority, like
    :func:`merge_k`; padded value slots carry zeros and are trimmed with
    their sentinel keys.
    """
    kstack, total = _stack_runs(key_runs)
    if isinstance(value_runs, jax.Array) or hasattr(value_runs, "shape"):
        vstack = jnp.asarray(value_runs)
    else:
        value_runs = [jnp.asarray(v) for v in value_runs]
        vd = jnp.result_type(*value_runs)
        width = kstack.shape[1]
        vstack = jnp.stack(
            [
                jnp.concatenate([v.astype(vd), jnp.zeros((width - v.shape[0],), vd)])
                for v in value_runs
            ]
        )
    if vstack.shape != kstack.shape:
        raise ValueError(f"key runs {kstack.shape} and value runs {vstack.shape} differ")
    k = kstack.shape[0]
    target = 1 << max(0, (k - 1).bit_length())
    if target != k:
        kpad = jnp.full((target - k, kstack.shape[1]), max_sentinel(kstack.dtype), kstack.dtype)
        vpad = jnp.zeros((target - k, vstack.shape[1]), vstack.dtype)
        kstack = jnp.concatenate([kstack, kpad], axis=0)
        vstack = jnp.concatenate([vstack, vpad], axis=0)
    while kstack.shape[0] > 1:
        kstack, vstack = merge_kv_batched(kstack[0::2], vstack[0::2], kstack[1::2], vstack[1::2])
    return kstack[0][:total], vstack[0][:total]


def _merge_k_groups(runs: jax.Array) -> jax.Array:
    """Merge every group of ``k`` sorted runs in ONE co-rank pass.

    ``runs`` is ``(G, k, w)``: G independent groups of k sorted width-w
    runs.  For run ``j``, an element's output position inside its group is
    its own index plus, for every other run ``j'``, the count of that
    run's elements preceding it — ``side="right"`` for ``j' < j`` (their
    ties come first) and ``side="left"`` for ``j' > j`` (our ties come
    first).  That is the stable multiway co-rank partition (Siebert &
    Träff, PAPERS.md): ``k*(k-1)`` fused rank searches but a single
    scatter pass over the data.  Returns ``(G, k*w)``.
    """
    g, k, w = runs.shape
    dtype = runs.dtype
    out = jnp.zeros((g, k * w), dtype)
    grp = jnp.arange(g, dtype=jnp.int32)[:, None]
    for j in range(k):
        q = runs[:, j]  # (G, w)
        rank = jnp.broadcast_to(jnp.arange(w, dtype=jnp.int32)[None, :], (g, w))
        for jp in range(k):
            if jp == j:
                continue
            side = "right" if jp < j else "left"
            rank = rank + searchsorted_batched(runs[:, jp], q, side=side)
        out = out.at[grp, rank].set(q)
    return out


def merge_sort_k(x: jax.Array, k: int = 4) -> jax.Array:
    """Bottom-up merge sort with fan-in ``k`` multiway rounds.

    ``k`` must be a power of two.  Each outer round merges every group of
    ``k`` consecutive sorted runs in a single co-rank pass
    (:func:`_merge_k_groups`), so the data is rewritten only
    ``ceil(log_k N)`` times instead of ``log2 N`` — the paper's merge sort
    generalized multiway, trading ``k - 1`` rank searches per element per
    round for fewer passes.  With ``k = 2`` this is exactly the paper's
    pairwise merge sort.
    """
    if k < 1 or (k & (k - 1)) != 0:
        raise ValueError(f"fan-in k must be a power of two, got {k}")
    n = x.shape[0]
    if n <= 1:
        return x
    xp = _pad_rows_pow2(x[None, :], max_sentinel(x.dtype))[0]
    m = xp.shape[0]
    fan_max = max(k, 2)  # k=1 degenerates to the pairwise sort
    width = 1
    while width < m:
        fan = min(fan_max, m // width)  # last round may have fewer runs than k
        xp = _merge_k_groups(xp.reshape(-1, fan, width)).reshape(-1)
        width *= fan
    return xp[:n]
