"""Batched & k-way Merge Path — the paper's partition, fused over a batch axis.

The paper's Segmented Parallel Merge is explicitly pitched as a building
block for "sorting and other functions" (§6).  This module generalizes the
pairwise 1-D primitives of :mod:`repro.core.merge_path` along the two axes
every real consumer needs:

* **Batched** (leading batch axis): ``merge_batched`` / ``merge_kv_batched``
  merge ``B`` independent pairs of sorted rows at once.  Instead of vmapping
  the scalar merge (which re-traces the bisection per lane), all ``B * n``
  diagonal binary searches run as *one* vectorized Algorithm 2 pass — the
  vector lanes play the role of the paper's cores across rows *and*
  diagonals simultaneously.  This is the form the Pallas kernel's 2-D
  ``(batch, tile)`` grid consumes (``repro.kernels.merge_path``).
* **k-way**: ``merge_k`` / ``merge_k_kv`` merge ``k`` sorted runs by a
  tournament of pairwise Merge Paths (``ceil(log2 k)`` batched rounds), the
  classic multiway generalization of the co-rank partition (cf. Träff,
  "Simplified, stable parallel merging", PAPERS.md).  ``merge_sort_k`` is
  the bottom-up sort whose outer rounds instead merge each group of ``k``
  runs in a *single* multiway co-rank pass, rewriting the data only
  ``ceil(log_k N)`` times; with ``k = 2`` it is exactly the paper's merge
  sort.

Conventions match :mod:`repro.core.merge_path`: rows sorted ascending,
merges stable with A-priority (ties take A first; original order kept
within each input).  Sentinel padding (``max_sentinel``) is used for
power-of-two round structure; payloads *equal* to the sentinel are safe:
pads are always appended after the real data, ties resolve by stability
toward the earlier position, and the ragged/key-value paths additionally
exclude pads from ranks by **length** rather than by comparison (see the
ragged section below), so a pad can never shadow a real ``+inf`` /
``iinfo.max`` key.

The ``*_ragged`` variants carry per-row valid lengths — each row's data
is a sorted *prefix* of its storage row — which is how production
batches actually arrive (per-request candidate counts, masked vocab,
variable bucket sizes).

Everything is jittable and shardable; no Python-level per-row loops.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from .merge_path import bisect_steps, flip_desc, max_sentinel, min_sentinel, total_order_keys

__all__ = [
    "searchsorted_batched",
    "diagonal_intersections_batched",
    "diagonal_intersections_ragged",
    "window_intersections",
    "merge_batched",
    "merge_kv_batched",
    "merge_batched_ragged",
    "merge_kv_batched_ragged",
    "merge_sort_batched",
    "merge_sort_kv_batched",
    "merge_sort_batched_ragged",
    "merge_sort_kv_batched_ragged",
    "stable_argsort_batched",
    "stable_argsort_batched_ragged",
    "topk_batched",
    "topk_batched_ragged",
    "merge_k",
    "merge_k_kv",
    "merge_k_onepass",
    "merge_sort_k",
]


def searchsorted_batched(sorted_rows: jax.Array, queries: jax.Array, side: str = "left") -> jax.Array:
    """Row-wise ``searchsorted``: one fused bisection over the whole batch.

    ``sorted_rows`` is ``(B, n)`` with each row ascending; ``queries`` is
    ``(B, m)``.  Returns ``(B, m)`` int32 insertion points, equal to
    ``jnp.searchsorted(sorted_rows[i], queries[i], side)`` per row.

    This is the cross-diagonal binary search of Algorithm 2 in its rank
    reading: with ``side="left"`` the result is ``|{j : row[j] < q}|``,
    with ``side="right"`` it is ``|{j : row[j] <= q}|`` — the two tie
    orientations that make the pairwise merge stable with A-priority.
    """
    if side not in ("left", "right"):
        raise ValueError(f"side must be 'left' or 'right', got {side!r}")
    b, n = sorted_rows.shape
    if n == 0:
        return jnp.zeros(queries.shape, jnp.int32)
    lo = jnp.zeros(queries.shape, jnp.int32)
    hi = jnp.full(queries.shape, n, jnp.int32)

    def body(_, lh):
        lo, hi = lh
        mid = (lo + hi) >> 1
        sv = jnp.take_along_axis(sorted_rows, jnp.clip(mid, 0, n - 1), axis=1)
        go_right = (sv < queries) if side == "left" else (sv <= queries)
        active = lo < hi
        lo2 = jnp.where(active & go_right, mid + 1, lo)
        hi2 = jnp.where(active & ~go_right, mid, hi)
        return lo2, hi2

    lo, hi = jax.lax.fori_loop(0, bisect_steps(n), body, (lo, hi))
    return lo


def diagonal_intersections_batched(a: jax.Array, b: jax.Array, diags: jax.Array) -> jax.Array:
    """Algorithm 2, vectorized over rows *and* diagonals at once.

    ``a`` is ``(B, na)``, ``b`` is ``(B, nb)``, ``diags`` is ``(D,)`` or
    ``(B, D)`` with ints in ``[0, na + nb]``.  Returns ``ai`` of shape
    ``(B, D)``: for batch row ``r`` and diagonal ``d``, the first ``d``
    outputs of the stable merge of ``a[r]`` and ``b[r]`` are
    ``a[r, :ai]`` and ``b[r, :d - ai]``.

    Equivalent to ``vmap(diagonal_intersections)`` but with a single
    fused bisection — one trip count, one gather per step, every
    ``(row, diagonal)`` pair in its own vector lane.
    """
    bsz, na = a.shape
    nb = b.shape[1]
    diags = jnp.asarray(diags, jnp.int32)
    if diags.ndim == 1:
        diags = jnp.broadcast_to(diags[None, :], (bsz, diags.shape[0]))
    if nb == 0:  # path is a straight vertical line
        return jnp.minimum(diags, na)
    if na == 0:  # straight horizontal line
        return jnp.zeros_like(diags)
    lo = jnp.maximum(0, diags - nb)
    hi = jnp.minimum(diags, na)

    def body(_, lh):
        lo, hi = lh
        mid = (lo + hi) >> 1
        av = jnp.take_along_axis(a, jnp.clip(mid, 0, na - 1), axis=1)
        bv = jnp.take_along_axis(b, jnp.clip(diags - 1 - mid, 0, nb - 1), axis=1)
        pred = av <= bv  # A-priority: A[i] precedes B[j] iff A[i] <= B[j]
        active = lo < hi
        lo2 = jnp.where(active & pred, mid + 1, lo)
        hi2 = jnp.where(active & ~pred, mid, hi)
        return lo2, hi2

    lo, hi = jax.lax.fori_loop(0, bisect_steps(min(na, nb)), body, (lo, hi))
    return lo


def diagonal_intersections_ragged(
    a: jax.Array, b: jax.Array, a_lens: jax.Array, b_lens: jax.Array, diags: jax.Array
) -> jax.Array:
    """Algorithm 2 over rows with per-row valid lengths.

    Like :func:`diagonal_intersections_batched`, but row ``r``'s inputs
    are the sorted prefixes ``a[r, :a_lens[r]]`` / ``b[r, :b_lens[r]]``
    and ``diags`` must lie in ``[0, a_lens[r] + b_lens[r]]`` (clip before
    calling).  The bisection interval is bounded by the row's *lengths*
    — ``lo = max(0, d - b_len)``, ``hi = min(d, a_len)`` — so every probe
    lands inside the valid prefixes and the search never compares against
    padding, whatever the tails contain.
    """
    bsz, na = a.shape
    nb = b.shape[1]
    a_lens = _as_lens(a_lens, bsz, na)
    b_lens = _as_lens(b_lens, bsz, nb)
    diags = jnp.asarray(diags, jnp.int32)
    if diags.ndim == 1:
        diags = jnp.broadcast_to(diags[None, :], (bsz, diags.shape[0]))
    if na == 0 or nb == 0:
        return jnp.minimum(diags, a_lens[:, None])
    lo = jnp.maximum(0, diags - b_lens[:, None])
    hi = jnp.minimum(diags, a_lens[:, None])

    def body(_, lh):
        lo, hi = lh
        mid = (lo + hi) >> 1
        av = jnp.take_along_axis(a, jnp.clip(mid, 0, na - 1), axis=1)
        bv = jnp.take_along_axis(b, jnp.clip(diags - 1 - mid, 0, nb - 1), axis=1)
        pred = av <= bv  # A-priority: A[i] precedes B[j] iff A[i] <= B[j]
        active = lo < hi
        lo2 = jnp.where(active & pred, mid + 1, lo)
        hi2 = jnp.where(active & ~pred, mid, hi)
        return lo2, hi2

    lo, hi = jax.lax.fori_loop(0, bisect_steps(min(na, nb)), body, (lo, hi))
    return lo


def window_intersections(
    wa: jax.Array,
    wb: jax.Array,
    diags: jax.Array,
    valid_a: jax.Array | None = None,
    valid_b: jax.Array | None = None,
) -> jax.Array:
    """Algorithm 2 over two fixed-size sorted *windows* — kernel-traceable.

    The shared bisection helper behind the hierarchical tile engine's
    level-2 split (:mod:`repro.kernels.merge_path`): given two sorted
    windows ``wa`` (Ta,) / ``wb`` (Tb,) and cross diagonals ``diags``
    (D,), returns ``ai`` (D,) such that the first ``d`` outputs of the
    stable A-priority merge of the windows are ``wa[:ai]`` and
    ``wb[:d-ai]``.  Identical math to :func:`diagonal_intersections`, but

    * operates on *values* (not refs), with a trip count fixed from the
      static window sizes, so it traces inside a Pallas kernel body;
    * optionally bounds the interval by traced scalar valid lengths
      ``valid_a`` / ``valid_b`` (the windows' real-data prefixes) so no
      probe ever compares against padding — callers must clamp ``diags``
      to ``valid_a + valid_b`` first.
    """
    na, nb = wa.shape[0], wb.shape[0]
    diags = jnp.asarray(diags, jnp.int32)
    if valid_a is None:
        lo = jnp.maximum(0, diags - nb)
        hi = jnp.minimum(diags, na)
    else:
        lo = jnp.maximum(0, diags - valid_b)
        hi = jnp.minimum(diags, valid_a)

    def body(_, lh):
        lo, hi = lh
        mid = (lo + hi) >> 1
        av = wa[jnp.clip(mid, 0, na - 1)]
        bv = wb[jnp.clip(diags - 1 - mid, 0, nb - 1)]
        pred = av <= bv  # A-priority: A[i] precedes B[j] iff A[i] <= B[j]
        active = lo < hi
        lo2 = jnp.where(active & pred, mid + 1, lo)
        hi2 = jnp.where(active & ~pred, mid, hi)
        return lo2, hi2

    lo, hi = jax.lax.fori_loop(0, bisect_steps(min(na, nb)), body, (lo, hi))
    return lo


def _batched_ranks(a: jax.Array, b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Cross-ranks of every element of every row pair, in one fused pass."""
    na, nb = a.shape[1], b.shape[1]
    ia = jnp.arange(na, dtype=jnp.int32)[None, :] + searchsorted_batched(b, a, side="left")
    ib = jnp.arange(nb, dtype=jnp.int32)[None, :] + searchsorted_batched(a, b, side="right")
    return ia, ib


def merge_batched(a: jax.Array, b: jax.Array) -> jax.Array:
    """Stable merge of ``B`` pairs of sorted rows: ``(B, na) + (B, nb) -> (B, na + nb)``.

    Row ``r`` of the result is exactly ``merge(a[r], b[r])`` (stable,
    A-priority) — bit-identical to the vmapped pairwise merge, but computed
    by a single vectorized Algorithm 2 pass: every element's output
    position is its cross-rank, and all ``B * (na + nb)`` rank searches
    share one fixed-trip bisection.
    """
    bsz, na = a.shape
    nb = b.shape[1]
    dtype = jnp.result_type(a, b)
    ia, ib = _batched_ranks(a, b)
    rows = jnp.arange(bsz, dtype=jnp.int32)[:, None]
    out = jnp.zeros((bsz, na + nb), dtype)
    out = out.at[rows, ia].set(a.astype(dtype))
    out = out.at[rows, ib].set(b.astype(dtype))
    return out


def merge_kv_batched(
    ak: jax.Array, av: jax.Array, bk: jax.Array, bv: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Stable batched key-value merge; row ``r`` equals ``merge_kv`` of row ``r``.

    ``ak``/``bk`` are ``(B, na)``/``(B, nb)`` sorted key rows; ``av``/``bv``
    the same-shape value rows carried along the permutation.
    """
    bsz, na = ak.shape
    nb = bk.shape[1]
    kd = jnp.result_type(ak, bk)
    vd = jnp.result_type(av, bv)
    ia, ib = _batched_ranks(ak, bk)
    rows = jnp.arange(bsz, dtype=jnp.int32)[:, None]
    keys = jnp.zeros((bsz, na + nb), kd).at[rows, ia].set(ak.astype(kd)).at[rows, ib].set(bk.astype(kd))
    vals = jnp.zeros((bsz, na + nb), vd).at[rows, ia].set(av.astype(vd)).at[rows, ib].set(bv.astype(vd))
    return keys, vals


# ---------------------------------------------------------------------------
# Ragged batched merges: per-row valid lengths
# ---------------------------------------------------------------------------
#
# Production batches are ragged: per-request candidate counts, per-row
# valid vocab, variable bucket sizes.  The ragged API carries a `(B,)`
# length vector per input; each row's valid data is a *prefix* of the
# fixed-width storage row (the padding tail's contents are ignored).
# Output rows hold the merged valid elements first and sentinel padding
# after.  Ranks are computed length-aware — pads are excluded by count,
# never by comparing against the sentinel — so payloads *equal* to the
# sentinel (real ``+inf`` keys, int ``iinfo.max``) merge correctly even
# in the key-value forms.


def _as_lens(lens, bsz: int, n: int) -> jax.Array:
    """Normalize a lengths argument to a clipped ``(B,)`` int32 vector."""
    lens = jnp.asarray(lens, jnp.int32)
    if lens.ndim == 0:
        lens = jnp.broadcast_to(lens, (bsz,))
    if lens.shape != (bsz,):
        raise ValueError(f"expected lengths of shape ({bsz},), got {lens.shape}")
    return jnp.clip(lens, 0, n)

def _mask_rows(x: jax.Array, lens: jax.Array, fill) -> jax.Array:
    """Replace entries at/after each row's length with ``fill``."""
    col = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
    return jnp.where(col < lens[:, None], x, jnp.asarray(fill, x.dtype))


def _ragged_ranks(
    a: jax.Array, b: jax.Array, a_lens: jax.Array, b_lens: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Length-aware cross-ranks; pad entries rank past the output row.

    ``a``/``b`` must already be sentinel-masked beyond their lengths (so
    rows are globally sorted).  The ``left`` search can never count pads
    (nothing is < the sentinel); the ``right`` search is capped at the
    cross row's valid length so pads tied with a sentinel-valued payload
    are not counted.
    """
    na, nb = a.shape[1], b.shape[1]
    n = na + nb
    ia = jnp.arange(na, dtype=jnp.int32)[None, :]
    ib = jnp.arange(nb, dtype=jnp.int32)[None, :]
    ra = ia + jnp.minimum(searchsorted_batched(b, a, side="left"), b_lens[:, None])
    rb = ib + jnp.minimum(searchsorted_batched(a, b, side="right"), a_lens[:, None])
    ra = jnp.where(ia < a_lens[:, None], ra, n)
    rb = jnp.where(ib < b_lens[:, None], rb, n)
    return ra, rb


def merge_batched_ragged(
    a: jax.Array, b: jax.Array, a_lens, b_lens
) -> jax.Array:
    """Stable merge of ``B`` row pairs with per-row valid lengths.

    ``a`` is ``(B, na)``, ``b`` is ``(B, nb)``; row ``r``'s valid data is
    the sorted prefix ``a[r, :a_lens[r]]`` / ``b[r, :b_lens[r]]`` (the
    tail contents are ignored).  Returns ``(B, na + nb)`` whose row ``r``
    starts with the stable A-priority merge of the two valid prefixes
    (``a_lens[r] + b_lens[r]`` elements) followed by sentinel padding.
    """
    bsz, na = a.shape
    nb = b.shape[1]
    if b.shape[0] != bsz:
        raise ValueError(f"batch mismatch: {a.shape} vs {b.shape}")
    a_lens = _as_lens(a_lens, bsz, na)
    b_lens = _as_lens(b_lens, bsz, nb)
    dtype = jnp.result_type(a, b)
    sent = max_sentinel(dtype)
    am = _mask_rows(a.astype(dtype), a_lens, sent)
    bm = _mask_rows(b.astype(dtype), b_lens, sent)
    ra, rb = _ragged_ranks(am, bm, a_lens, b_lens)
    rows = jnp.arange(bsz, dtype=jnp.int32)[:, None]
    out = jnp.full((bsz, na + nb), sent, dtype)
    out = out.at[rows, ra].set(am, mode="drop")
    out = out.at[rows, rb].set(bm, mode="drop")
    return out


def merge_kv_batched_ragged(
    ak: jax.Array, av: jax.Array, bk: jax.Array, bv: jax.Array, a_lens, b_lens
) -> Tuple[jax.Array, jax.Array]:
    """Ragged stable key-value merge; see :func:`merge_batched_ragged`.

    Output values past a row's merged length are zero (key slots are
    sentinel).  Safe for payload keys equal to the sentinel: pads are
    excluded from ranks by length, so they can never shadow a real
    ``+inf`` / ``iinfo.max`` key and leak a zero value.
    """
    if av.shape != ak.shape or bv.shape != bk.shape:
        raise ValueError(
            f"value shapes must match key shapes: keys {ak.shape}/{bk.shape}, "
            f"values {av.shape}/{bv.shape}"
        )
    bsz, na = ak.shape
    nb = bk.shape[1]
    if bk.shape[0] != bsz:
        raise ValueError(f"batch mismatch: {ak.shape} vs {bk.shape}")
    a_lens = _as_lens(a_lens, bsz, na)
    b_lens = _as_lens(b_lens, bsz, nb)
    kd = jnp.result_type(ak, bk)
    vd = jnp.result_type(av, bv)
    sent = max_sentinel(kd)
    akm = _mask_rows(ak.astype(kd), a_lens, sent)
    bkm = _mask_rows(bk.astype(kd), b_lens, sent)
    ra, rb = _ragged_ranks(akm, bkm, a_lens, b_lens)
    rows = jnp.arange(bsz, dtype=jnp.int32)[:, None]
    keys = jnp.full((bsz, na + nb), sent, kd)
    keys = keys.at[rows, ra].set(akm, mode="drop").at[rows, rb].set(bkm, mode="drop")
    vals = jnp.zeros((bsz, na + nb), vd)
    vals = vals.at[rows, ra].set(av.astype(vd), mode="drop")
    vals = vals.at[rows, rb].set(bv.astype(vd), mode="drop")
    return keys, vals


def merge_sort_batched_ragged(x: jax.Array, lens) -> jax.Array:
    """Sort each row's valid prefix ascending; tail slots become sentinel.

    Pads are sentinel-masked *before* the sort; stability keeps real
    sentinel-valued payloads (which start at positions < ``lens[r]``)
    ahead of the pads, so the first ``lens[r]`` outputs are exactly the
    sorted valid prefix.
    """
    bsz, n = x.shape
    lens = _as_lens(lens, bsz, n)
    if jnp.issubdtype(x.dtype, jnp.floating):
        # NaN-deterministic route: mask pads in int total-order key space,
        # where the pad sentinel (iinfo.max) is *strictly* above every real
        # key — including NaN (canonical-NaN bits) and real +inf — so NaN
        # keys sort to the end of the valid prefix, never into the tail.
        tok = total_order_keys(x)
        tok = _mask_rows(tok, lens, max_sentinel(tok.dtype))
        _, out = merge_sort_kv_batched(tok, x)
        col = jnp.arange(n, dtype=jnp.int32)[None, :]
        return jnp.where(col < lens[:, None], out, max_sentinel(x.dtype))
    return merge_sort_batched(_mask_rows(x, lens, max_sentinel(x.dtype)))


def merge_sort_kv_batched_ragged(
    keys: jax.Array, values: jax.Array, lens
) -> Tuple[jax.Array, jax.Array]:
    """Ragged row-wise stable kv-sort (keys ascending over each valid prefix).

    Row ``r``'s first ``lens[r]`` output pairs are the stably sorted
    valid pairs; the tail carries sentinel keys with the masked slots'
    original values (in original order), so the value row remains a
    permutation of the input row.
    """
    bsz, n = keys.shape
    lens = _as_lens(lens, bsz, n)
    if jnp.issubdtype(keys.dtype, jnp.floating):
        # see merge_sort_batched_ragged: pads are masked in int total-order
        # key space so NaN keys stay inside the valid prefix (sorted last)
        tok = total_order_keys(keys)
        tok = _mask_rows(tok, lens, max_sentinel(tok.dtype))
        idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (bsz, n))
        _, perm = merge_sort_kv_batched(tok, idx)
        rows = jnp.arange(bsz, dtype=jnp.int32)[:, None]
        ks = keys[rows, perm]
        col = jnp.arange(n, dtype=jnp.int32)[None, :]
        ks = jnp.where(col < lens[:, None], ks, max_sentinel(keys.dtype))
        return ks, values[rows, perm]
    return merge_sort_kv_batched(
        _mask_rows(keys, lens, max_sentinel(keys.dtype)), values
    )


def stable_argsort_batched_ragged(keys: jax.Array, lens) -> jax.Array:
    """Ragged row-wise stable argsort: the first ``lens[r]`` entries of row
    ``r`` are ``np.argsort(keys[r, :lens[r]], kind="stable")``; the tail
    lists the masked positions in original order (a full permutation)."""
    bsz, n = keys.shape
    idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (bsz, n))
    _, perm = merge_sort_kv_batched_ragged(keys, idx, lens)
    return perm


def topk_batched_ragged(x: jax.Array, k: int, lens) -> Tuple[jax.Array, jax.Array]:
    """Row-wise descending top-k over each row's valid prefix.

    Returns ``(values, indices)``, each ``(B, min(k, n))`` — like
    :func:`topk_batched` (and ``jax.lax.top_k`` callers expect), ``k``
    silently truncates to the row width.  Slots ``j >= lens[r]`` (rows
    with fewer valid candidates than ``k``) return index ``-1`` and the
    dtype's minimum value.  Tie-breaking matches ``jax.lax.top_k``
    (smallest index first); int inputs containing ``iinfo.min`` are
    handled exactly (:func:`repro.core.merge_path.flip_desc`).
    """
    bsz, n = x.shape
    k = min(k, n)
    lens = _as_lens(lens, bsz, n)
    perm = stable_argsort_batched_ragged(flip_desc(x), lens)
    top_idx = perm[:, :k]
    vals = jnp.take_along_axis(x, top_idx, axis=1)
    slot_valid = jnp.arange(k, dtype=jnp.int32)[None, :] < lens[:, None]
    vals = jnp.where(slot_valid, vals, min_sentinel(x.dtype))
    top_idx = jnp.where(slot_valid, top_idx, -1)
    return vals, top_idx


def _pad_rows_pow2(x: jax.Array, fill) -> jax.Array:
    """Pad the last axis of ``(B, n)`` to the next power of two with ``fill``."""
    n = x.shape[1]
    m = 1 << max(0, (n - 1).bit_length())
    if m == n:
        return x
    pad = jnp.full((x.shape[0], m - n), fill, x.dtype)
    return jnp.concatenate([x, pad], axis=1)


def merge_sort_batched(x: jax.Array) -> jax.Array:
    """Sort every row of ``(B, n)`` ascending via batched merge-path rounds.

    The classic bottom-up structure of the paper's merge sort, but each of
    the ``log2 n`` rounds merges *all* runs of *all* rows in one
    :func:`merge_batched` call — batch and pair axes are flattened
    together, so the vector utilization is independent of where we are in
    the round schedule.

    Float rows route through :func:`repro.core.merge_path.total_order_keys`
    — the merge network compares same-width int keys while the float
    payload rides along as the value — so NaN keys sort last,
    deterministically, instead of poisoning the ``<=`` comparisons.  For
    NaN-free input the int key order coincides with the float order and
    the result is bit-identical to sorting the floats directly.
    """
    bsz, n = x.shape
    if n <= 1:
        return x
    if jnp.issubdtype(x.dtype, jnp.floating):
        _, out = merge_sort_kv_batched(total_order_keys(x), x)
        return out
    xp = _pad_rows_pow2(x, max_sentinel(x.dtype))
    m = xp.shape[1]
    width = 1
    while width < m:
        runs = xp.reshape(-1, 2, width)  # (B * m/2w, 2, w)
        xp = merge_batched(runs[:, 0], runs[:, 1]).reshape(bsz, m)
        width *= 2
    return xp[:, :n]


def merge_sort_kv_batched(keys: jax.Array, values: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Row-wise stable key-value sort of ``(B, n)`` keys (ascending).

    Stability is inherited from the A-priority pairwise merge, making this
    the batched form of the dispatch sort MoE relies on for deterministic
    capacity drops.

    Float keys take the NaN-deterministic route: the permutation is
    computed by kv-sorting the int :func:`total_order_keys` of the keys
    (NaN last), then both keys and values are gathered through it — the
    output keys are the *original* float bit patterns in sorted order.
    Bit-identical to the direct float sort whenever no key is NaN.
    """
    bsz, n = keys.shape
    if n <= 1:
        return keys, values
    if jnp.issubdtype(keys.dtype, jnp.floating):
        idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (bsz, n))
        _, perm = merge_sort_kv_batched(total_order_keys(keys), idx)
        rows = jnp.arange(bsz, dtype=jnp.int32)[:, None]
        return keys[rows, perm], values[rows, perm]
    kp = _pad_rows_pow2(keys, max_sentinel(keys.dtype))
    vp = _pad_rows_pow2(values, jnp.zeros((), values.dtype))
    m = kp.shape[1]
    width = 1
    while width < m:
        kr = kp.reshape(-1, 2, width)
        vr = vp.reshape(-1, 2, width)
        kp, vp = merge_kv_batched(kr[:, 0], vr[:, 0], kr[:, 1], vr[:, 1])
        kp = kp.reshape(bsz, m)
        vp = vp.reshape(bsz, m)
        width *= 2
    return kp[:, :n], vp[:, :n]


def stable_argsort_batched(keys: jax.Array) -> jax.Array:
    """Row-wise stable argsort (ascending) of ``(B, n)`` keys."""
    bsz, n = keys.shape
    idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (bsz, n))
    _, perm = merge_sort_kv_batched(keys, idx)
    return perm


def topk_batched(x: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Row-wise descending top-k of ``(B, n)``: ``(values, indices)``, each ``(B, k)``.

    Stable like :func:`repro.core.merge_path.topk_desc` (among equal values
    the smallest index wins, matching ``jax.lax.top_k``), but all rows ride
    one batched kv-sort instead of a vmapped per-row sort.  Descending
    order comes from the order-flipped keys of
    :func:`repro.core.merge_path.flip_desc` (bitwise NOT for ints — exact
    at ``iinfo.min``, where negation would wrap).
    """
    perm = stable_argsort_batched(flip_desc(x))
    top_idx = perm[:, :k]
    return jnp.take_along_axis(x, top_idx, axis=1), top_idx


# ---------------------------------------------------------------------------
# k-way tournament merges
# ---------------------------------------------------------------------------

def _stack_runs(runs, lens=None):
    """Normalize a ``(k, n)`` array or a sequence of sorted 1-D runs.

    Returns ``(stacked, lens, static_total)`` where ``lens`` is the
    ``(k,)`` int32 per-run valid lengths and ``static_total`` is the
    total true length when it is known at trace time (list input, or an
    array with no ``lens``) — ``None`` for a caller-supplied ``lens``
    (possibly traced), in which case the merged output cannot be trimmed
    to a data-dependent shape.  Ragged list runs are sentinel-padded to
    the longest.
    """
    if isinstance(runs, jax.Array) or hasattr(runs, "shape"):
        runs = jnp.asarray(runs)
        if runs.ndim != 2:
            raise ValueError(f"expected (k, n) runs, got shape {runs.shape}")
        if lens is None:
            k, n = runs.shape
            return runs, jnp.full((k,), n, jnp.int32), k * n
        return runs, _as_lens(lens, runs.shape[0], runs.shape[1]), None
    if lens is not None:
        raise ValueError("lens is only valid with a stacked (k, n) runs array")
    runs = [jnp.asarray(r) for r in runs]
    if not runs:
        raise ValueError("merge_k needs at least one run")
    dtype = jnp.result_type(*runs)
    width = max(r.shape[0] for r in runs)
    sent = max_sentinel(dtype)
    padded = [
        jnp.concatenate([r.astype(dtype), jnp.full((width - r.shape[0],), sent, dtype)])
        for r in runs
    ]
    lens_arr = jnp.array([r.shape[0] for r in runs], jnp.int32)
    return jnp.stack(padded), lens_arr, sum(r.shape[0] for r in runs)


def merge_k(runs, lens=None) -> jax.Array:
    """Merge ``k`` sorted runs into one sorted array via a pairwise tournament.

    ``runs`` is a ``(k, n)`` array of sorted rows, or a sequence of sorted
    1-D arrays (possibly ragged — shorter runs are sentinel-padded).  With
    a stacked array, ``lens`` optionally gives each row's valid length
    (the tail is ignored) — the ragged form consumed by
    ``distributed_sort``'s variable bucket counts.  The tournament runs
    ``ceil(log2 k)`` rounds; round ``j`` merges ``k / 2^j`` run pairs with
    one :func:`merge_batched_ragged` call, i.e. the co-rank partition
    applied multiway exactly as in the stable multiway merges of Träff et
    al. (PAPERS.md).  ``k = 1`` is the identity.

    Stable across runs in input order: ties resolve toward the
    lower-indexed run (tournament rounds always merge lower-index runs as
    the A side).  Output: all valid elements merged, then sentinel
    padding; when the total true length is static (list input, or no
    ``lens``) the padding is trimmed off, otherwise the row is exactly
    ``(k * n,)`` wide.  Valid lengths ride through every round, so
    payloads equal to the sentinel are merged exactly (no
    strictly-below-sentinel caveat).
    """
    stacked, run_lens, static_total = _stack_runs(runs, lens)
    if static_total is None:
        # caller-supplied lens: sentinel-normalize the tails up front so the
        # output contract (valid prefix, then sentinel) holds even for the
        # k == 1 identity, which runs no merge round
        stacked = _mask_rows(stacked, run_lens, max_sentinel(stacked.dtype))
    k, n = stacked.shape
    target = 1 << max(0, (k - 1).bit_length())
    if target != k:
        pad = jnp.full((target - k, stacked.shape[1]), max_sentinel(stacked.dtype), stacked.dtype)
        stacked = jnp.concatenate([stacked, pad], axis=0)
        run_lens = jnp.concatenate([run_lens, jnp.zeros((target - k,), jnp.int32)])
    while stacked.shape[0] > 1:
        stacked = merge_batched_ragged(
            stacked[0::2], stacked[1::2], run_lens[0::2], run_lens[1::2]
        )
        run_lens = run_lens[0::2] + run_lens[1::2]
    # pow2 pad rows only contribute trailing sentinels: (k * n,) is enough
    out = stacked[0][: k * n]
    return out if static_total is None else out[:static_total]


def merge_k_kv(key_runs, value_runs, lens=None) -> Tuple[jax.Array, jax.Array]:
    """Key-value :func:`merge_k`: merge ``k`` sorted (keys, values) runs.

    ``key_runs`` / ``value_runs`` are matching ``(k, n)`` arrays or
    sequences of matching 1-D runs; ``lens`` optionally gives per-run
    valid lengths for a stacked array.  Stable with lower-run priority,
    like :func:`merge_k`.  Output: merged valid pairs first, then
    sentinel keys with zero values (trimmed when the total is static,
    ``(k * n,)`` wide otherwise).
    Lengths (not sentinel comparisons) exclude the padding, so payload
    keys equal to the sentinel keep their values — the failure mode of
    the pre-ragged tournament.
    """
    kstack, run_lens, static_total = _stack_runs(key_runs, lens)
    if isinstance(value_runs, jax.Array) or hasattr(value_runs, "shape"):
        vstack = jnp.asarray(value_runs)
    else:
        value_runs = [jnp.asarray(v) for v in value_runs]
        vd = jnp.result_type(*value_runs)
        width = kstack.shape[1]
        vstack = jnp.stack(
            [
                jnp.concatenate([v.astype(vd), jnp.zeros((width - v.shape[0],), vd)])
                for v in value_runs
            ]
        )
    if vstack.shape != kstack.shape:
        raise ValueError(f"key runs {kstack.shape} and value runs {vstack.shape} differ")
    if static_total is None:
        # see merge_k: normalize tails so the k == 1 identity honors the
        # sentinel-keys / zero-values output contract
        kstack = _mask_rows(kstack, run_lens, max_sentinel(kstack.dtype))
        vstack = _mask_rows(vstack, run_lens, jnp.zeros((), vstack.dtype))
    k, n = kstack.shape
    target = 1 << max(0, (k - 1).bit_length())
    if target != k:
        kpad = jnp.full((target - k, kstack.shape[1]), max_sentinel(kstack.dtype), kstack.dtype)
        vpad = jnp.zeros((target - k, vstack.shape[1]), vstack.dtype)
        kstack = jnp.concatenate([kstack, kpad], axis=0)
        vstack = jnp.concatenate([vstack, vpad], axis=0)
        run_lens = jnp.concatenate([run_lens, jnp.zeros((target - k,), jnp.int32)])
    while kstack.shape[0] > 1:
        kstack, vstack = merge_kv_batched_ragged(
            kstack[0::2], vstack[0::2], kstack[1::2], vstack[1::2],
            run_lens[0::2], run_lens[1::2],
        )
        run_lens = run_lens[0::2] + run_lens[1::2]
    if static_total is None:
        # pow2 pad rows only contribute trailing sentinel/zero pairs
        return kstack[0][: k * n], vstack[0][: k * n]
    return kstack[0][:static_total], vstack[0][:static_total]


def merge_k_onepass(runs, lens=None) -> jax.Array:
    """Merge ``k`` sorted runs in ONE multiway co-rank pass — no rounds.

    Same contract as :func:`merge_k` (stable with lower-run priority;
    ragged ``lens`` supported; output is the merged valid prefix followed
    by sentinel padding, trimmed when the total is static), but instead of
    ``ceil(log2 k)`` tournament rounds that rewrite the data every round,
    each element's final output position is computed directly: its own
    index plus, for every other run, the count of that run's valid
    elements preceding it — ``side="right"`` against lower-indexed runs
    (their ties come first) and ``side="left"`` against higher-indexed
    runs (our ties come first).  That is Siebert & Träff's stable multiway
    co-rank partition (PAPERS.md): ``O(k²)`` rank searches but a *single*
    scatter pass over the data, the right trade when runs are long and
    ``k`` is a mesh-sized constant — this is ``distributed_sort``'s
    default bucket combine (``combine="onepass"``).

    Length-capped counts exclude padding by *count*, never by comparing
    against the sentinel, so payloads equal to the sentinel merge exactly
    (the same guarantee as the ragged tournament).
    """
    stacked, run_lens, static_total = _stack_runs(runs, lens)
    k, n = stacked.shape
    sent = max_sentinel(stacked.dtype)
    sm = _mask_rows(stacked, run_lens, sent)
    if k == 1:
        out = sm[0] if static_total is None else stacked[0][:static_total]
        return out
    total = k * n
    out = jnp.full((total,), sent, stacked.dtype)
    t = jnp.arange(n, dtype=jnp.int32)
    jidx = jnp.arange(k, dtype=jnp.int32)[:, None]
    for j in range(k):
        q = jnp.broadcast_to(sm[j][None, :], (k, n))
        # counts of each run's elements preceding run j's elements; capped
        # at the run's valid length so pads are excluded by count
        cl = jnp.minimum(searchsorted_batched(sm, q, side="left"), run_lens[:, None])
        cr = jnp.minimum(searchsorted_batched(sm, q, side="right"), run_lens[:, None])
        cross = jnp.where(jidx < j, cr, cl)
        cross = jnp.where(jidx == j, 0, cross)
        rank = t + jnp.sum(cross, axis=0)
        rank = jnp.where(t < run_lens[j], rank, total)  # pads drop
        out = out.at[rank].set(sm[j], mode="drop")
    return out if static_total is None else out[:static_total]


def _merge_k_groups(runs: jax.Array) -> jax.Array:
    """Merge every group of ``k`` sorted runs in ONE co-rank pass.

    ``runs`` is ``(G, k, w)``: G independent groups of k sorted width-w
    runs.  For run ``j``, an element's output position inside its group is
    its own index plus, for every other run ``j'``, the count of that
    run's elements preceding it — ``side="right"`` for ``j' < j`` (their
    ties come first) and ``side="left"`` for ``j' > j`` (our ties come
    first).  That is the stable multiway co-rank partition (Siebert &
    Träff, PAPERS.md): ``k*(k-1)`` fused rank searches but a single
    scatter pass over the data.  Returns ``(G, k*w)``.
    """
    g, k, w = runs.shape
    dtype = runs.dtype
    out = jnp.zeros((g, k * w), dtype)
    grp = jnp.arange(g, dtype=jnp.int32)[:, None]
    for j in range(k):
        q = runs[:, j]  # (G, w)
        rank = jnp.broadcast_to(jnp.arange(w, dtype=jnp.int32)[None, :], (g, w))
        for jp in range(k):
            if jp == j:
                continue
            side = "right" if jp < j else "left"
            rank = rank + searchsorted_batched(runs[:, jp], q, side=side)
        out = out.at[grp, rank].set(q)
    return out


def merge_sort_k(x: jax.Array, k: int = 4) -> jax.Array:
    """Bottom-up merge sort with fan-in ``k`` multiway rounds.

    ``k`` must be a power of two.  Each outer round merges every group of
    ``k`` consecutive sorted runs in a single co-rank pass
    (:func:`_merge_k_groups`), so the data is rewritten only
    ``ceil(log_k N)`` times instead of ``log2 N`` — the paper's merge sort
    generalized multiway, trading ``k - 1`` rank searches per element per
    round for fewer passes.  With ``k = 2`` this is exactly the paper's
    pairwise merge sort.
    """
    if k < 1 or (k & (k - 1)) != 0:
        raise ValueError(f"fan-in k must be a power of two, got {k}")
    n = x.shape[0]
    if n <= 1:
        return x
    xp = _pad_rows_pow2(x[None, :], max_sentinel(x.dtype))[0]
    m = xp.shape[0]
    fan_max = max(k, 2)  # k=1 degenerates to the pairwise sort
    width = 1
    while width < m:
        fan = min(fan_max, m // width)  # last round may have fewer runs than k
        xp = _merge_k_groups(xp.reshape(-1, fan, width)).reshape(-1)
        width *= fan
    return xp[:n]
