"""Segmented Parallel Merge (SPM) — Algorithm 3 of the paper, in JAX.

The paper breaks the Merge Path into cache-sized (C/3) segments, merging
one segment at a time with all p cores cooperating, so that everything
live co-resides in cache.  On TPU the "cache" is VMEM and the production
form of SPM is the Pallas kernel in ``repro.kernels.merge_path`` (each
grid step stages <= L elements of each input through VMEM, double-buffered
by the pipeline).  This module keeps a pure-jnp SPM whose *schedule* is
the paper's, used as an oracle for the kernel and as the CPU fallback.

Key guarantee (Lemma 16 / Theorem 17): a path segment of length L consumes
at most L consecutive elements of A and at most L consecutive elements of
B, and the segment's p sub-partitions can be found from those 2L elements
alone — so each outer iteration touches a bounded window.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .merge_path import diagonal_intersections, max_sentinel


def _window_merge(wa: jax.Array, wb: jax.Array, out_len: int) -> jax.Array:
    """Merge the first ``out_len`` outputs of two sorted windows.

    Rank-based (the tile form used by the Pallas kernel): cross-ranks via
    comparisons, then scatter.  Elements whose rank lands beyond
    ``out_len`` belong to a later segment and are dropped here (they are
    re-staged by that segment's window — the paper's "not all elements
    will be used" remark after Thm 17).
    """
    L = wa.shape[0]
    dtype = jnp.result_type(wa, wb)
    ra = jnp.arange(L, dtype=jnp.int32) + jnp.searchsorted(wb, wa, side="left").astype(jnp.int32)
    rb = jnp.arange(L, dtype=jnp.int32) + jnp.searchsorted(wa, wb, side="right").astype(jnp.int32)
    out = jnp.zeros(out_len, dtype)
    out = out.at[jnp.where(ra < out_len, ra, out_len)].set(wa.astype(dtype), mode="drop")
    out = out.at[jnp.where(rb < out_len, rb, out_len)].set(wb.astype(dtype), mode="drop")
    return out


def segmented_merge(a: jax.Array, b: jax.Array, segment: int) -> jax.Array:
    """SPM: merge A and B in output segments of ``segment`` elements.

    A ``lax.scan`` walks the segments in order, carrying the global
    (a_offset, b_offset) path position — the ``startingPoint`` of
    Algorithm 3.  Within a segment, work is fully parallel (vectorized
    rank computation = the p cooperating cores).
    """
    na, nb = a.shape[0], b.shape[0]
    n = na + nb
    if n % segment != 0:
        raise ValueError(f"|A|+|B| = {n} must be divisible by segment = {segment}")
    num_seg = n // segment
    dtype = jnp.result_type(a, b)
    # Sentinel-pad so fixed-size windows never read out of bounds; pads are
    # +inf so they always lose comparisons and ranks stay correct.
    ap = jnp.concatenate([a.astype(dtype), jnp.full((segment,), max_sentinel(dtype))])
    bp = jnp.concatenate([b.astype(dtype), jnp.full((segment,), max_sentinel(dtype))])

    def step(carry, _):
        a_off, b_off = carry
        wa = jax.lax.dynamic_slice(ap, (a_off,), (segment,))
        wb = jax.lax.dynamic_slice(bp, (b_off,), (segment,))
        out = _window_merge(wa, wb, segment)
        # End-of-segment path position: local diagonal `segment` within the
        # window == global diagonal advance (Theorem 17).
        da = diagonal_intersections(wa, wb, jnp.array([segment], jnp.int32))[0]
        return (a_off + da, b_off + (segment - da)), out

    (_, _), outs = jax.lax.scan(step, (jnp.int32(0), jnp.int32(0)), None, length=num_seg)
    return outs.reshape(n)


def segmented_merge_kv(
    ak: jax.Array, av: jax.Array, bk: jax.Array, bv: jax.Array, segment: int
) -> Tuple[jax.Array, jax.Array]:
    """Key-value SPM (stable, A-priority)."""
    na, nb = ak.shape[0], bk.shape[0]
    n = na + nb
    if n % segment != 0:
        raise ValueError(f"|A|+|B| = {n} must be divisible by segment = {segment}")
    num_seg = n // segment
    kd = jnp.result_type(ak, bk)
    vd = jnp.result_type(av, bv)
    akp = jnp.concatenate([ak.astype(kd), jnp.full((segment,), max_sentinel(kd))])
    bkp = jnp.concatenate([bk.astype(kd), jnp.full((segment,), max_sentinel(kd))])
    avp = jnp.concatenate([av.astype(vd), jnp.zeros((segment,), vd)])
    bvp = jnp.concatenate([bv.astype(vd), jnp.zeros((segment,), vd)])

    def step(carry, _):
        a_off, b_off = carry
        wak = jax.lax.dynamic_slice(akp, (a_off,), (segment,))
        wbk = jax.lax.dynamic_slice(bkp, (b_off,), (segment,))
        wav = jax.lax.dynamic_slice(avp, (a_off,), (segment,))
        wbv = jax.lax.dynamic_slice(bvp, (b_off,), (segment,))
        L = segment
        ra = jnp.arange(L, dtype=jnp.int32) + jnp.searchsorted(wbk, wak, side="left").astype(jnp.int32)
        rb = jnp.arange(L, dtype=jnp.int32) + jnp.searchsorted(wak, wbk, side="right").astype(jnp.int32)
        ra = jnp.where(ra < L, ra, L)
        rb = jnp.where(rb < L, rb, L)
        ko = jnp.zeros(L, kd).at[ra].set(wak, mode="drop").at[rb].set(wbk, mode="drop")
        vo = jnp.zeros(L, vd).at[ra].set(wav, mode="drop").at[rb].set(wbv, mode="drop")
        da = diagonal_intersections(wak, wbk, jnp.array([segment], jnp.int32))[0]
        return (a_off + da, b_off + (segment - da)), (ko, vo)

    (_, _), (ks, vs) = jax.lax.scan(step, (jnp.int32(0), jnp.int32(0)), None, length=num_seg)
    return ks.reshape(n), vs.reshape(n)
