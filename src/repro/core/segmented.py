"""Segmented Parallel Merge (SPM) — Algorithm 3 of the paper, in JAX.

The paper breaks the Merge Path into cache-sized (C/3) segments, merging
one segment at a time with all p cores cooperating, so that everything
live co-resides in cache.  On TPU the "cache" is VMEM and the production
form of SPM is the Pallas kernel in ``repro.kernels.merge_path`` (each
grid step stages <= L elements of each input through VMEM, double-buffered
by the pipeline).  This module keeps a pure-jnp SPM whose *schedule* is
the paper's, used as an oracle for the kernel and as the CPU fallback.

Key guarantee (Lemma 16 / Theorem 17): a path segment of length L consumes
at most L consecutive elements of A and at most L consecutive elements of
B, and the segment's p sub-partitions can be found from those 2L elements
alone — so each outer iteration touches a bounded window.

Length-awareness: ``|A| + |B|`` need **not** divide evenly by the segment
size — the grid is ceil-div and the last segment is short.  Windows that
overrun an input are sentinel-padded, but ranks and the path advance are
computed from the windows' *valid lengths*, never from comparisons
against the sentinel — so payloads equal to the sentinel (real ``+inf``
keys, int ``iinfo.max``) merge correctly, including in the key-value
form where a pad/payload mix-up would surface pad values.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .merge_path import max_sentinel


def _masked_window_ranks(
    wa: jax.Array, wb: jax.Array, valid_a: jax.Array, valid_b: jax.Array, out_len: int
) -> Tuple[jax.Array, jax.Array]:
    """Cross-ranks of two sorted windows, counting only valid elements.

    ``wa``/``wb`` are fixed-size windows whose first ``valid_a``/``valid_b``
    entries are real data and whose tail is sentinel padding.  Rank = own
    index + number of *valid* cross elements preceding (A-priority ties).
    The ``side="left"`` count never includes pads (nothing is < the
    sentinel); the ``side="right"`` count is capped at ``valid_a`` so pads
    tied with a sentinel-valued payload are not counted.  Pad entries get
    rank ``out_len``; valid elements can also rank past ``out_len`` —
    both are dropped by the caller's scatter, the latter belonging to a
    later segment that re-stages them through its own window (the
    paper's "not all elements will be used" remark after Thm 17).
    """
    L = wa.shape[0]
    io = jnp.arange(L, dtype=jnp.int32)
    ra = io + jnp.minimum(
        jnp.searchsorted(wb, wa, side="left").astype(jnp.int32), valid_b
    )
    rb = io + jnp.minimum(
        jnp.searchsorted(wa, wb, side="right").astype(jnp.int32), valid_a
    )
    ra = jnp.where(io < valid_a, ra, out_len)
    rb = jnp.where(io < valid_b, rb, out_len)
    return ra, rb


def segmented_merge(a: jax.Array, b: jax.Array, segment: int) -> jax.Array:
    """SPM: merge A and B in output segments of ``segment`` elements.

    A ``lax.scan`` walks the segments in order, carrying the global
    (a_offset, b_offset) path position — the ``startingPoint`` of
    Algorithm 3.  Within a segment, work is fully parallel (vectorized
    rank computation = the p cooperating cores).  ``|A| + |B|`` may be
    any size: the grid is ``ceil(N / segment)`` and the last segment is
    short.
    """
    na, nb = a.shape[0], b.shape[0]
    n = na + nb
    if segment < 1:
        raise ValueError(f"segment must be >= 1, got {segment}")
    num_seg = -(-n // segment)  # ceil-div: last segment may be short
    dtype = jnp.result_type(a, b)
    # Sentinel-pad so fixed-size windows never read out of bounds; ranks
    # and the path advance only ever count the windows' valid prefixes.
    ap = jnp.concatenate([a.astype(dtype), jnp.full((segment,), max_sentinel(dtype))])
    bp = jnp.concatenate([b.astype(dtype), jnp.full((segment,), max_sentinel(dtype))])
    io = jnp.arange(segment, dtype=jnp.int32)

    def step(carry, _):
        a_off, b_off = carry
        wa = jax.lax.dynamic_slice(ap, (a_off,), (segment,))
        wb = jax.lax.dynamic_slice(bp, (b_off,), (segment,))
        valid_a = jnp.clip(na - a_off, 0, segment)
        valid_b = jnp.clip(nb - b_off, 0, segment)
        ra, rb = _masked_window_ranks(wa, wb, valid_a, valid_b, segment)
        out = jnp.zeros(segment, dtype).at[ra].set(wa, mode="drop").at[rb].set(wb, mode="drop")
        # End-of-segment path position: exactly the valid elements whose
        # rank fell inside this segment were consumed (Theorem 17).
        da = jnp.sum((ra < segment).astype(jnp.int32))
        db = jnp.sum((rb < segment).astype(jnp.int32))
        return (a_off + da, b_off + db), out

    (_, _), outs = jax.lax.scan(
        step, (jnp.int32(0), jnp.int32(0)), None, length=num_seg
    )
    return outs.reshape(num_seg * segment)[:n]


def segmented_merge_kv(
    ak: jax.Array, av: jax.Array, bk: jax.Array, bv: jax.Array, segment: int
) -> Tuple[jax.Array, jax.Array]:
    """Key-value SPM (stable, A-priority).

    Like :func:`segmented_merge`, residue-free (any ``|A| + |B|``) and
    safe for payload keys equal to the sentinel: pads are excluded from
    ranks by window length, not by comparison, so a pad can never shadow
    a real ``+inf`` / ``iinfo.max`` key and leak its zero value.
    """
    na, nb = ak.shape[0], bk.shape[0]
    n = na + nb
    if segment < 1:
        raise ValueError(f"segment must be >= 1, got {segment}")
    num_seg = -(-n // segment)
    kd = jnp.result_type(ak, bk)
    vd = jnp.result_type(av, bv)
    akp = jnp.concatenate([ak.astype(kd), jnp.full((segment,), max_sentinel(kd))])
    bkp = jnp.concatenate([bk.astype(kd), jnp.full((segment,), max_sentinel(kd))])
    avp = jnp.concatenate([av.astype(vd), jnp.zeros((segment,), vd)])
    bvp = jnp.concatenate([bv.astype(vd), jnp.zeros((segment,), vd)])

    def step(carry, _):
        a_off, b_off = carry
        wak = jax.lax.dynamic_slice(akp, (a_off,), (segment,))
        wbk = jax.lax.dynamic_slice(bkp, (b_off,), (segment,))
        wav = jax.lax.dynamic_slice(avp, (a_off,), (segment,))
        wbv = jax.lax.dynamic_slice(bvp, (b_off,), (segment,))
        valid_a = jnp.clip(na - a_off, 0, segment)
        valid_b = jnp.clip(nb - b_off, 0, segment)
        ra, rb = _masked_window_ranks(wak, wbk, valid_a, valid_b, segment)
        ko = jnp.zeros(segment, kd).at[ra].set(wak, mode="drop").at[rb].set(wbk, mode="drop")
        vo = jnp.zeros(segment, vd).at[ra].set(wav, mode="drop").at[rb].set(wbv, mode="drop")
        da = jnp.sum((ra < segment).astype(jnp.int32))
        db = jnp.sum((rb < segment).astype(jnp.int32))
        return (a_off + da, b_off + db), (ko, vo)

    (_, _), (ks, vs) = jax.lax.scan(
        step, (jnp.int32(0), jnp.int32(0)), None, length=num_seg
    )
    return ks.reshape(num_seg * segment)[:n], vs.reshape(num_seg * segment)[:n]
