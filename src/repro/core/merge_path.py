"""Merge Path (Green, Odeh & Birk 2014) — pure-JAX core.

The paper's central object: merging sorted arrays A and B corresponds to a
monotone staircase path on the |A|x|B| grid.  The path's intersection with
cross-diagonal ``d`` (the set of cells with ``i + j = d``) is the unique
1->0 transition of the binary merge matrix ``M[i, j] = A[i] > B[j]`` along
that diagonal (paper Corollary 12 / Proposition 13), so it is found by a
binary search costing ``O(log min(|A|, |B|))`` comparisons (Theorem 14).

Everything here is jittable, vmappable and shardable.  Conventions:

* Arrays are 1-D and sorted ascending.
* Merges are **stable with A-priority**: on ties, elements of A precede
  elements of B (and within each array original order is kept).  This is
  what makes the key-value sort below a *stable* sort, which MoE dispatch
  relies on for deterministic capacity-drop order.
* ``diagonal_intersections(a, b, d)`` returns ``ai`` = number of elements
  of A among the first ``d`` outputs of the merge; ``bi = d - ai``.
"""

from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "max_sentinel",
    "min_sentinel",
    "flip_desc",
    "total_order_keys",
    "bisect_steps",
    "diagonal_intersections",
    "merge",
    "merge_kv",
    "partitioned_merge",
    "merge_sort",
    "merge_sort_kv",
    "stable_argsort",
    "topk",
    "topk_desc",
]


def max_sentinel(dtype) -> jnp.ndarray:
    """Largest value for ``dtype``, used to pad sorted runs.

    Floats use ``+inf`` (not ``finfo.max``) so that real ``+inf`` payloads
    — e.g. the flipped keys of ``-inf`` logits in top-k — tie with the
    padding instead of sorting after it; stability then keeps every real
    element ahead of the pads, which are always appended last.  The same
    tie-then-stability argument covers int payloads equal to
    ``iinfo.max``.
    """
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def min_sentinel(dtype) -> jnp.ndarray:
    """Smallest value for ``dtype`` (``-inf`` / ``iinfo.min``).

    Used to fill top-k value slots past a row's valid length, so masked
    slots can never outrank real candidates.
    """
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(-jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).min, dtype)


def flip_desc(x: jax.Array) -> jax.Array:
    """Strictly order-reversing key transform: ``x < y  <=>  flip(x) > flip(y)``.

    Floats negate.  Ints use bitwise NOT (``~x == -x - 1``), which is an
    exact order-reversing bijection with **no overflow**: ``-x`` wraps at
    ``iinfo.min`` (UB in C, silent wraparound here — ``-iinfo.min ==
    iinfo.min``), whereas ``~iinfo.min == iinfo.max``.  Sorting flipped
    keys ascending with a stable sort therefore yields a stable
    *descending* order for every dtype, including int arrays containing
    ``iinfo.min``.
    """
    if jnp.issubdtype(x.dtype, jnp.floating):
        return -x
    return ~x


def total_order_keys(x: jax.Array) -> jax.Array:
    """IEEE-754 total-order key transform: comparable keys for float arrays.

    NaN keys break ``<=`` comparisons nondeterministically — every engine
    (searchsorted core, Pallas hier/matrix, distributed window exchange)
    may disagree on where an unordered element lands.  This transform maps
    floats to same-width *signed ints* whose int order is a total order
    refining the float order:

    1. canonicalize: ``-0.0 -> +0.0`` and every NaN (any sign/payload) to
       the canonical quiet NaN, so equal-comparing floats get equal keys;
    2. bitcast to the same-width signed int ``i``;
    3. ``key = i`` for nonnegative floats, ``key = iinfo.min ^ ~i`` for
       negative ones — a monotone flip of the negative range.

    Resulting order: ``-inf < ... < -0.0 == +0.0 < ... < +inf < NaN``
    (the canonical NaN bit pattern, e.g. ``0x7FC00000`` for f32, exceeds
    the ``+inf`` pattern ``0x7F800000``).  **NaN sorts last,
    deterministically, on every engine.**  All keys are strictly inside
    ``(iinfo.min, iinfo.max)``, so the int ``min_sentinel``/``max_sentinel``
    still strictly bracket every real key.

    Non-float inputs are returned unchanged (int orders are already
    total).  The input is wrapped in ``stop_gradient``: bitcasts are not
    differentiable, and gradients flow through the value gather/scatter of
    the permutation the keys induce, never through the keys themselves.
    """
    if not jnp.issubdtype(x.dtype, jnp.floating):
        return x
    x = jax.lax.stop_gradient(x)
    itemsize = jnp.dtype(x.dtype).itemsize
    int_dtype = {2: jnp.int16, 4: jnp.int32, 8: jnp.int64}[itemsize]
    canon_nan = jnp.array(jnp.nan, x.dtype)  # canonical quiet NaN
    x = jnp.where(jnp.isnan(x), canon_nan, x + jnp.zeros((), x.dtype))  # +0 folds -0.0 -> +0.0
    bits = jax.lax.bitcast_convert_type(x, int_dtype)
    imin = jnp.array(jnp.iinfo(int_dtype).min, int_dtype)
    return jnp.where(bits < 0, imin ^ ~bits, bits)


def bisect_steps(span: int) -> int:
    """Fixed trip count that guarantees a bisection over an interval of
    length ``span + 1`` converges (each step at least halves the interval).

    This is THE trip counter for every fixed-trip binary search in the
    repo — the diagonal searches here (where a cross diagonal has at most
    ``min(|A|, |B|)`` cells, paper Thm 14, so ``span = min(|A|, |B|)``),
    the batched/ragged searches in :mod:`repro.core.batched`, and the
    kernel-side level-2 sub-diagonal split in
    :mod:`repro.kernels.merge_path`.  Deriving the count from the
    theorem's bound keeps every search jittable (no data-dependent trip
    counts) without a per-call-site re-derivation.
    """
    return max(1, int(math.ceil(math.log2(span + 1))) + 1)


def diagonal_intersections(a: jax.Array, b: jax.Array, diags: jax.Array) -> jax.Array:
    """Vectorized Algorithm 2 of the paper.

    For every cross diagonal ``d`` in ``diags`` (ints in [0, |A|+|B|]),
    find the Merge Path intersection: returns ``ai`` with ``0<=ai<=|A|``
    such that the first ``d`` outputs of the stable merge consist of
    ``A[:ai]`` and ``B[:d-ai]``.

    All diagonals are searched simultaneously on the VPU with a fixed trip
    count — the paper's per-core independent searches, with vector lanes
    playing the role of cores.
    """
    na, nb = a.shape[0], b.shape[0]
    diags = jnp.asarray(diags, jnp.int32)
    if nb == 0:  # path is a straight vertical line
        return jnp.minimum(diags, na)
    if na == 0:  # straight horizontal line
        return jnp.zeros_like(diags)
    lo = jnp.maximum(0, diags - nb)
    hi = jnp.minimum(diags, na)

    def body(_, lh):
        lo, hi = lh
        mid = (lo + hi) >> 1
        # Predicate: does A[mid] precede B[d-1-mid] in the stable merge?
        # (A-priority: A[i] precedes B[j] iff A[i] <= B[j].)
        av = a[jnp.clip(mid, 0, na - 1)]
        bv = b[jnp.clip(diags - 1 - mid, 0, nb - 1)]
        pred = av <= bv
        active = lo < hi
        lo2 = jnp.where(active & pred, mid + 1, lo)
        hi2 = jnp.where(active & ~pred, mid, hi)
        return lo2, hi2

    lo, hi = jax.lax.fori_loop(0, bisect_steps(min(na, nb)), body, (lo, hi))
    return lo


def merge(a: jax.Array, b: jax.Array) -> jax.Array:
    """Stable merge of two sorted arrays — flat rank-based form.

    Every element's output position is its cross-rank: ``rank(A[i]) = i +
    |{j : B[j] < A[i]}|`` and ``rank(B[j]) = j + |{i : A[i] <= B[j]}|``.
    The cross-rank is exactly the cross diagonal on which the Merge Path
    consumes the element, so this is the "all diagonals at once" reading of
    the paper.  Depth O(log N), work O(N log N): the right trade on a
    machine with 10^5 parallel lanes per core.
    """
    na, nb = a.shape[0], b.shape[0]
    ia = jnp.arange(na, dtype=jnp.int32) + jnp.searchsorted(b, a, side="left").astype(jnp.int32)
    ib = jnp.arange(nb, dtype=jnp.int32) + jnp.searchsorted(a, b, side="right").astype(jnp.int32)
    out = jnp.zeros(na + nb, dtype=jnp.result_type(a, b))
    out = out.at[ia].set(a.astype(out.dtype))
    out = out.at[ib].set(b.astype(out.dtype))
    return out


def merge_kv(
    ak: jax.Array, av: jax.Array, bk: jax.Array, bv: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Stable key-value merge: returns merged (keys, values)."""
    na, nb = ak.shape[0], bk.shape[0]
    ia = jnp.arange(na, dtype=jnp.int32) + jnp.searchsorted(bk, ak, side="left").astype(jnp.int32)
    ib = jnp.arange(nb, dtype=jnp.int32) + jnp.searchsorted(ak, bk, side="right").astype(jnp.int32)
    kd = jnp.result_type(ak, bk)
    vd = jnp.result_type(av, bv)
    keys = jnp.zeros(na + nb, kd).at[ia].set(ak.astype(kd)).at[ib].set(bk.astype(kd))
    vals = jnp.zeros(na + nb, vd).at[ia].set(av.astype(vd)).at[ib].set(bv.astype(vd))
    return keys, vals


def partitioned_merge(a: jax.Array, b: jax.Array, p: int) -> jax.Array:
    """Algorithm 1 of the paper, faithfully: p independent segment merges.

    The output is cut into ``p`` segments at equispaced cross diagonals;
    each vmap lane ("core") finds its (a_start, b_start) by the diagonal
    binary search and then runs the sequential two-pointer merge for
    ``ceil(N/p)`` steps.  Zero inter-lane communication, perfect load
    balance (Corollary 7).  This is the reference parallelization used by
    the benchmarks; the Pallas kernel is its TPU-tile form.

    ``N`` need not divide evenly by ``p``: the last segment is simply
    short (its diagonal is clamped to ``N`` and the overrun is trimmed),
    matching the paper's remark that the partition works for arbitrary
    ``|A|, |B|`` — the same ceil-div + clamped-diagonal scheme as the
    Pallas kernel's ``_prepare``.
    """
    na, nb = a.shape[0], b.shape[0]
    n = na + nb
    if p < 1:
        raise ValueError(f"p must be >= 1, got {p}")
    dtype0 = jnp.result_type(a, b)
    if na == 0:
        return b.astype(dtype0)
    if nb == 0:
        return a.astype(dtype0)
    seg = -(-n // p)  # ceil-div: last segment may be short
    diags = jnp.minimum(jnp.arange(p, dtype=jnp.int32) * seg, n)
    a_starts = diagonal_intersections(a, b, diags)
    b_starts = diags - a_starts
    dtype = jnp.result_type(a, b)

    def seg_merge(ai0, bi0):
        def step(carry, _):
            ai, bi = carry
            av = a[jnp.minimum(ai, na - 1)].astype(dtype)
            bv = b[jnp.minimum(bi, nb - 1)].astype(dtype)
            take_a = (bi >= nb) | ((ai < na) & (av <= bv))
            out = jnp.where(take_a, av, bv)
            ta = take_a.astype(jnp.int32)
            return (ai + ta, bi + (1 - ta)), out

        (_, _), outs = jax.lax.scan(step, (ai0, bi0), None, length=seg)
        return outs

    return jax.vmap(seg_merge)(a_starts, b_starts).reshape(p * seg)[:n]


def _pad_pow2(x: jax.Array, fill) -> jax.Array:
    n = x.shape[0]
    m = 1 << max(0, (n - 1).bit_length())
    if m == n:
        return x
    return jnp.concatenate([x, jnp.full((m - n,), fill, x.dtype)])


def merge_sort(x: jax.Array) -> jax.Array:
    """Bottom-up merge sort built from pairwise merge-path merges.

    ``log2 N`` rounds; round ``r`` merges ``N / 2^(r+1)`` disjoint pairs of
    sorted runs of length ``2^r`` — exactly the paper's merge-sort
    structure (§1, §3), with the early rounds trivially parallel over pairs
    and the late rounds parallel *within* each merge.  Each round is one
    fused :func:`repro.core.batched.merge_batched` pass (pairs stacked on
    the batch axis), so every round saturates the vector lanes regardless
    of run width.  This is the singleton-batch case of
    :func:`repro.core.batched.merge_sort_batched`.
    """
    from .batched import merge_sort_batched  # local import: batched builds on this module

    if x.shape[0] <= 1:
        return x
    return merge_sort_batched(x[None, :])[0]


def merge_sort_kv(keys: jax.Array, values: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Stable bottom-up key-value merge sort (keys ascending).

    Rounds are fused :func:`repro.core.batched.merge_kv_batched` passes —
    the singleton-batch case of
    :func:`repro.core.batched.merge_sort_kv_batched`.
    """
    from .batched import merge_sort_kv_batched  # local import: batched builds on this module

    if keys.shape[0] <= 1:
        return keys, values
    ks, vs = merge_sort_kv_batched(keys[None, :], values[None, :])
    return ks[0], vs[0]


def stable_argsort(keys: jax.Array) -> jax.Array:
    """Stable argsort (ascending) via the key-value merge sort."""
    idx = jnp.arange(keys.shape[0], dtype=jnp.int32)
    _, perm = merge_sort_kv(keys, idx)
    return perm


def topk_desc(x: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """(values, indices) of the k largest elements, descending, stable.

    Sorts order-flipped keys (:func:`flip_desc` — bitwise NOT for ints,
    so no wraparound at ``iinfo.min``) with the stable kv-sort so that
    among equal values the smallest index wins — matching
    ``jax.lax.top_k`` tie-breaking.
    """
    keys = flip_desc(x)
    idx = jnp.arange(x.shape[0], dtype=jnp.int32)
    _, perm = merge_sort_kv(keys, idx)
    top_idx = perm[:k]
    return x[top_idx], top_idx


def topk(x: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Alias of :func:`topk_desc` (descending top-k, like lax.top_k)."""
    return topk_desc(x, k)
