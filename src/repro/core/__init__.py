"""Merge Path core — the paper's contribution as composable JAX modules."""

from .merge_path import (
    diagonal_intersections,
    merge,
    merge_kv,
    merge_sort,
    merge_sort_kv,
    max_sentinel,
    partitioned_merge,
    stable_argsort,
    topk,
    topk_desc,
)
from .segmented import segmented_merge, segmented_merge_kv
from .distributed import (
    distributed_merge,
    distributed_merge_local,
    distributed_sort,
    distributed_sort_local,
    distributed_topk,
    distributed_topk_local,
)

__all__ = [
    "diagonal_intersections",
    "merge",
    "merge_kv",
    "merge_sort",
    "merge_sort_kv",
    "max_sentinel",
    "partitioned_merge",
    "stable_argsort",
    "topk",
    "topk_desc",
    "segmented_merge",
    "segmented_merge_kv",
    "distributed_merge",
    "distributed_merge_local",
    "distributed_sort",
    "distributed_sort_local",
    "distributed_topk",
    "distributed_topk_local",
]
