"""Distributed Merge Path — the paper's algorithm lifted to a device mesh.

The paper partitions one merge across p cores sharing a cache; here the
"cores" are TPU chips sharing an ICI, the partition math is identical, and
the shared cache is replaced by explicit collectives.  Every primitive
comes in two exchange flavors:

* ``exchange="window"`` (default, **bandwidth-optimal**): the paper's
  global diagonal intersection (Alg. 2) runs *collectively* — each probe
  of a remote element is a tiny ``psum`` (the memory fabric of the
  shared-cache machine becomes the mesh interconnect), so every device
  ends up with the exact, replicated cut table ``a_cuts[k] =
  intersection(k * seg)``.  Corollary 7 then says device ``i``'s 1/P
  output segment consumes *exactly* ``A[a_cuts[i]:a_cuts[i+1]]`` and
  ``B[b_cuts[i]:b_cuts[i+1]]`` — disjoint, consecutive windows covering
  the inputs — so one ``all_to_all`` of per-(sender, receiver) window
  pieces moves each element **once**: O(N/P) payload per device instead
  of the gather's O(N).  Pieces ride in fixed-size rows padded to the
  provable max-piece bound (:func:`window_bounds`; XLA collectives are
  static-shape — a ``ragged_all_to_all`` backend would make wire bytes
  equal payload bytes), and the merge itself is the ragged length-masked
  rank merge, so sentinel-valued payloads are exact.
* ``exchange="gather"``: the original Megatron-style all_gather body —
  bandwidth-suboptimal (every element moves P-1 times) but
  latency-optimal, kept as the bit-exactness oracle.  Both flavors share
  the same cut math and the same window-rank merge tail, and are fuzzed
  bit-identical in ``tests/test_distributed.py``.

Primitives:

* ``distributed_merge`` / ``distributed_merge_kv`` and their ``*_batched``
  forms: A and B sharded contiguously over the axis; each device returns
  exactly its 1/P slice of the merged output.
* ``distributed_sort``: one-round splitter-bucketed sample sort — local
  sort (optionally the Pallas hier engine via ``local_sort="pallas"``),
  splitter selection from a P*P sample, ONE all_to_all bucket exchange
  (each element moves once), then a local ragged combine of the P
  received runs: ``combine="onepass"`` (default) is the single multiway
  co-rank pass of :func:`repro.core.batched.merge_k_onepass`,
  ``combine="tournament"`` the log(P)-round pairwise tournament (rounds
  on the Pallas ragged kernel when ``local_sort="pallas"``).
* ``distributed_topk`` / ``distributed_topk_batched``: per-shard
  merge-path top-k, then either a log2(P) **butterfly** combine
  (``exchange="butterfly"``, default for power-of-two P: k·log2(P)
  elements moved per device) or an all_gather of the P candidate runs
  (``exchange="gather"``, P·k per device) merged by ``merge_k_kv``.
  Used for vocab-sharded sampling in serving.

Self-healing: every public wrapper routes its *eager* calls through
``repro.runtime.resilience.guarded_call`` with always-on output
verification (the distributed perf anchor gates exchanged bytes, so the
host-side check is free w.r.t. CI): merges degrade
``window -> gather -> core-resort``, the sample sort escalates capacity
(``sample -> capacity-2x -> core-resort``; escalation changes the padded
output shape — slice by the returned counts), and top-k degrades
``butterfly -> gather -> core-topk``.  Traced calls (inside ``jit`` or a
caller's ``shard_map``) bypass the guard: a per-device divergent fallback
would deadlock the collectives.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6: top-level export, replication check renamed to check_vma
    from jax import shard_map as _shard_map_impl

    _CHECK_KW = "check_vma"
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
    """Version-portable ``shard_map``: accepts either replication-check
    kwarg name and translates to the installed jax's spelling.  Defaults
    the check off (this repo's bodies use untyped collectives), but an
    explicit ``check_vma=True`` / ``check_rep=True`` is honored."""
    check = kwargs.pop("check_vma", None)
    if check is None:
        check = kwargs.pop("check_rep", None)
    else:
        kwargs.pop("check_rep", None)
    kwargs[_CHECK_KW] = False if check is None else check
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def _axis_size(axis_name: str) -> int:
    """``jax.lax.axis_size`` compat (added after 0.4.x)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)

from .batched import (
    _mask_rows,
    _ragged_ranks,
    diagonal_intersections_batched,
    merge_k,
    merge_k_kv,
    merge_k_onepass,
    merge_kv_batched,
    merge_sort_batched,
    merge_sort_kv_batched,
    topk_batched,
)
from .merge_path import (
    bisect_steps,
    diagonal_intersections,
    flip_desc,
    max_sentinel,
    merge_sort,
    merge_sort_kv,
    total_order_keys,
)
from .segmented import _masked_window_ranks

# Module-form imports (not ``from repro.runtime import ...``): the runtime
# package imports ``repro.core`` back, so during a cycle only the
# sys.modules entries exist — binding the (possibly still-initialising)
# module objects here and deferring attribute access to call time keeps
# both import orders working.
import repro.runtime.faults as _faults
import repro.runtime.resilience as _res

# telemetry is dependency-free (stdlib only) — no cycle risk
from repro.telemetry import get_telemetry as _get_telemetry


# ---------------------------------------------------------------------------
# window partition math (shared by implementation, tests, and benchmarks)
# ---------------------------------------------------------------------------

def window_bounds(na: int, nb: int, p: int) -> Tuple[int, int, int, int, int]:
    """Static bounds of the window exchange: ``(seg, W_a, W_b, w_a, w_b)``.

    ``seg`` is the per-device output segment (ceil-div, Corollary 7).
    ``W_a``/``W_b`` bound any device's A/B *window* length: a ``seg``-output
    segment consumes at most ``seg`` consecutive elements of each input
    (Lemma 16), and never more than the whole input.  ``w_a``/``w_b``
    bound any single (sender, receiver) *piece*: a piece is the overlap of
    one sender's contiguous shard (``ceil(n/p)`` elements) with one
    receiver's window, so it is capped by both.

    These are theorems, not heuristics — the fuzz tests assert the true
    window/piece sizes never exceed them, which is what guarantees the
    fixed-size exchange buffers can never silently truncate data.
    """
    if p < 1:
        raise ValueError(f"p must be >= 1, got {p}")
    n = na + nb
    seg = -(-n // p)
    m_a = -(-na // p) if na else 0
    m_b = -(-nb // p) if nb else 0
    W_a = max(1, min(na, seg))
    W_b = max(1, min(nb, seg))
    w_a = max(1, min(m_a, W_a))
    w_b = max(1, min(m_b, W_b))
    return seg, W_a, W_b, w_a, w_b


def exchange_bytes(
    na: int, nb: int, p: int, itemsize: int, kv: bool = False, rows: int = 1
) -> dict:
    """Per-device element-bytes moved by each exchange flavor (analytic).

    ``gather``: every device receives the other ``p-1`` shards of both
    inputs (and both value arrays when ``kv``) — O(N) per device.
    ``window`` payload: each device receives exactly its output segment's
    windows (``alen + blen = seg`` elements, O(N/P)) plus the collective
    bisection's probe traffic (``2 * bisect_steps`` psums of an
    ``(rows, p+1)`` buffer — ``rows`` is the batch size of the
    ``*_batched`` forms, whose every row carries its own cut table).
    ``window`` wire: what the dense static-shape ``all_to_all`` actually
    ships with pieces padded to the max-piece bound — a
    ``ragged_all_to_all`` backend would collapse wire to payload.
    All data terms scale linearly in ``rows``.
    """
    seg, W_a, W_b, w_a, w_b = window_bounds(na, nb, p)
    # same guarded ceil-div as window_bounds — keep the two in lockstep
    m_a = -(-na // p) if na else 0
    m_b = -(-nb // p) if nb else 0
    nval = (2 if kv else 1) * rows
    gather = (p - 1) * (m_a + m_b) * itemsize * nval
    probes = 2 * bisect_steps(min(na, nb)) * rows * (p + 1) * itemsize
    payload = seg * itemsize * nval + probes
    wire = p * (w_a + w_b) * itemsize * nval + probes
    return {
        "gather": gather,
        "window_payload": payload,
        "window_wire_padded": wire,
        "seg": seg,
        "max_window": (W_a, W_b),
        "max_piece": (w_a, w_b),
    }


# ---------------------------------------------------------------------------
# collective diagonal intersections (Algorithm 2 over the mesh)
# ---------------------------------------------------------------------------

def _collective_intersections(
    a_sh: jax.Array,
    b_sh: jax.Array,
    diags: jax.Array,
    na: int,
    nb: int,
    axis_name: str,
    idx: jax.Array,
) -> jax.Array:
    """Algorithm 2's diagonal bisection with *collective* memory probes.

    ``a_sh``/``b_sh`` are this device's contiguous ``(R, m)`` shards of
    the global ``(R, na)``/``(R, nb)`` sorted rows; ``diags`` is ``(D,)``
    global cross diagonals.  The bisection state is replicated (every
    device runs the identical search), and each probe of ``A[g]`` /
    ``B[g]`` is one ``psum``: the owning device contributes the element,
    everyone else zero.  ``2 * bisect_steps(min(na, nb))`` psums of tiny
    ``(R, D)`` buffers total — the paper's O(p log N) partition stage
    (Table 1, col 1) with the shared cache replaced by the interconnect.

    Returns the replicated ``(R, D)`` a-side cuts.
    """
    r, m_a = a_sh.shape
    m_b = b_sh.shape[1]
    dg = jnp.broadcast_to(jnp.asarray(diags, jnp.int32)[None, :], (r, diags.shape[0]))
    if na == 0 or nb == 0:
        return jnp.minimum(dg, na)

    def probe(shard, g, m):
        own = g // m
        loc = jnp.clip(g - own * m, 0, m - 1)
        v = jnp.take_along_axis(shard, loc, axis=1)
        mine = jnp.where(own == idx, v, jnp.zeros((), shard.dtype))
        return jax.lax.psum(mine, axis_name)

    lo = jnp.maximum(0, dg - nb)
    hi = jnp.minimum(dg, na)

    def body(_, lh):
        lo, hi = lh
        mid = (lo + hi) >> 1
        av = probe(a_sh, jnp.clip(mid, 0, na - 1), m_a)
        bv = probe(b_sh, jnp.clip(dg - 1 - mid, 0, nb - 1), m_b)
        pred = av <= bv  # A-priority: A[i] precedes B[j] iff A[i] <= B[j]
        active = lo < hi
        lo2 = jnp.where(active & pred, mid + 1, lo)
        hi2 = jnp.where(active & ~pred, mid, hi)
        return lo2, hi2

    lo, hi = jax.lax.fori_loop(0, bisect_steps(min(na, nb)), body, (lo, hi))
    return lo


# ---------------------------------------------------------------------------
# the window exchange (one all_to_all, each element moves once)
# ---------------------------------------------------------------------------

def _exchange_windows(
    shards,  # sequence of ((R, m) shard, fill) sharing the same cut table
    cuts: jax.Array,  # (R, p+1) replicated global cut table
    w_piece: int,
    W: int,
    p: int,
    axis_name: str,
    idx: jax.Array,
):
    """Move each device's exact input window to it with one all_to_all.

    The cut table partitions the global index space into P consecutive,
    disjoint receiver windows ``[cuts[i], cuts[i+1])``.  Sender side:
    device ``j`` slices, for every receiver ``i``, the overlap of its own
    shard ``[j*m, (j+1)*m)`` with window ``i`` — each element is in
    exactly one piece, so each element is sent exactly once.  Pieces ride
    in ``(p, R, w_piece)`` rows (``w_piece`` = the provable max-piece
    bound of :func:`window_bounds`).  Receiver side: the piece lengths
    are recomputed locally from the replicated cut table (no extra
    collective) and the pieces are scattered at their running offsets
    into a ``(R, W)`` window buffer pre-filled with ``fill``.

    Returns ``(windows, wlen)``: one ``(R, W)`` buffer per input shard
    (fill-padded past the window length) and the ``(R,)`` window lengths.
    """
    r, m = shards[0][0].shape
    my_lo = idx * m
    # sender side only needs each piece's start (the receiver's scatter
    # mask, built from the same replicated cuts, bounds its length)
    lo_i = jnp.maximum(cuts[:, :-1], my_lo)  # (R, p) per-receiver piece starts
    start_loc = jnp.clip(lo_i - my_lo, 0, m)  # (R, p) piece start in my shard
    gcols = start_loc.T[:, :, None] + jnp.arange(w_piece, dtype=jnp.int32)[None, None, :]

    # receiver-side reassembly plan, from the replicated cuts alone
    c0 = jax.lax.dynamic_slice_in_dim(cuts, idx, 1, axis=1)[:, 0]  # (R,)
    c1 = jax.lax.dynamic_slice_in_dim(cuts, idx + 1, 1, axis=1)[:, 0]
    wlen = c1 - c0
    j_lo = jnp.arange(p, dtype=jnp.int32)[None, :] * m  # (1, p) sender shard starts
    cnt_recv = jnp.clip(
        jnp.minimum(c1[:, None], j_lo + m) - jnp.maximum(c0[:, None], j_lo), 0, m
    )  # (R, p) piece length from each sender
    offs = jnp.cumsum(cnt_recv, axis=1) - cnt_recv  # (R, p) exclusive
    pos = offs.T[:, :, None] + jnp.arange(w_piece, dtype=jnp.int32)[None, None, :]
    valid = jnp.arange(w_piece, dtype=jnp.int32)[None, None, :] < cnt_recv.T[:, :, None]
    pos = jnp.where(valid, pos, W)  # (p, R, w_piece); W = out-of-bounds drop
    rows = jnp.broadcast_to(jnp.arange(r, dtype=jnp.int32)[None, :, None], pos.shape)

    windows = []
    for shard, fill in shards:
        shard_p = jnp.concatenate(
            [shard, jnp.full((r, w_piece), fill, shard.dtype)], axis=1
        )
        send = jnp.take_along_axis(
            jnp.broadcast_to(shard_p[None], (p,) + shard_p.shape), gcols, axis=2
        )  # (p, R, w_piece)
        recv = jax.lax.all_to_all(
            send, axis_name, split_axis=0, concat_axis=0, tiled=True
        )  # (p, R, w_piece): sender j's piece for me
        win = jnp.full((r, W), fill, shard.dtype)
        win = win.at[rows, pos].set(recv, mode="drop")
        windows.append(win)
    return windows, wlen


# ---------------------------------------------------------------------------
# distributed merge (keys-only and key-value, 1-D and batched)
# ---------------------------------------------------------------------------

def _segment_from_windows(wa, wb, alen, blen, seg, va=None, vb=None):
    """Merge two fill-padded ragged windows into this device's segment.

    ``wa``/``wb`` are ``(R, W)`` windows sentinel-masked past
    ``alen``/``blen`` (and ``va``/``vb`` the zero-masked value windows for
    the kv form).  Ranks are length-masked (PR 2's ragged contract), so
    padding is excluded by count — payload keys equal to the sentinel
    merge exactly.  Because the windows are *exactly* the segment's
    inputs, ``alen + blen <= seg`` and every valid rank lands in-bounds.
    """
    ra, rb = _ragged_ranks(wa, wb, alen, blen)
    r = wa.shape[0]
    rows = jnp.arange(r, dtype=jnp.int32)[:, None]
    keys = jnp.full((r, seg), max_sentinel(wa.dtype), wa.dtype)
    keys = keys.at[rows, ra].set(wa, mode="drop").at[rows, rb].set(wb, mode="drop")
    if va is None:
        return keys, None
    vals = jnp.zeros((r, seg), va.dtype)
    vals = vals.at[rows, ra].set(va, mode="drop").at[rows, rb].set(vb, mode="drop")
    return keys, vals


def _merge_local_body(
    ak_sh, av_sh, bk_sh, bv_sh, *, axis_name, na, nb, p, exchange
):
    """Per-device body shared by every distributed merge variant.

    Shards are ``(R, m)`` (R = batch rows, R = 1 for the 1-D forms), with
    the last shard sentinel-padded past the true ``na``/``nb``.  Returns
    this device's ``(R, seg)`` output segment (keys, values-or-None).
    """
    idx = jax.lax.axis_index(axis_name)
    n = na + nb
    seg, W_a, W_b, w_a, w_b = window_bounds(na, nb, p)
    kv = av_sh is not None
    sent = max_sentinel(ak_sh.dtype)

    if exchange == "gather":
        # bandwidth-suboptimal oracle: replicate everything, slice windows
        a_full = jax.lax.all_gather(ak_sh, axis_name, tiled=True, axis=1)[:, :na]
        b_full = jax.lax.all_gather(bk_sh, axis_name, tiled=True, axis=1)[:, :nb]
        d0 = jnp.minimum(idx * seg, n)
        d1 = jnp.minimum(d0 + seg, n)
        dg = jnp.stack([d0, d1]).astype(jnp.int32)  # (2,)
        cuts2 = diagonal_intersections_batched(a_full, b_full, dg)  # (R, 2)
        a0, alen = cuts2[:, 0], cuts2[:, 1] - cuts2[:, 0]
        b0, blen = d0 - cuts2[:, 0], (d1 - d0) - (cuts2[:, 1] - cuts2[:, 0])

        def take_window(full, start, W, fill):
            fp = jnp.concatenate(
                [full, jnp.full((full.shape[0], W), fill, full.dtype)], axis=1
            )
            cols = start[:, None] + jnp.arange(W, dtype=jnp.int32)[None, :]
            return jnp.take_along_axis(fp, cols, axis=1)

        wa = _mask_rows(take_window(a_full, a0, W_a, sent), alen, sent)
        wb = _mask_rows(take_window(b_full, b0, W_b, sent), blen, sent)
        va = vb = None
        if kv:
            av_f = jax.lax.all_gather(av_sh, axis_name, tiled=True, axis=1)[:, :na]
            bv_f = jax.lax.all_gather(bv_sh, axis_name, tiled=True, axis=1)[:, :nb]
            va = _mask_rows(take_window(av_f, a0, W_a, 0), alen, 0)
            vb = _mask_rows(take_window(bv_f, b0, W_b, 0), blen, 0)
        return _segment_from_windows(wa, wb, alen, blen, seg, va, vb)

    if exchange != "window":
        raise ValueError(f"exchange must be 'window' or 'gather', got {exchange!r}")
    # bandwidth-optimal: collective Alg. 2 for the replicated cut table,
    # then ONE all_to_all per array moving each element exactly once
    diags = np.minimum(np.arange(p + 1, dtype=np.int32) * seg, n)
    a_cuts = _collective_intersections(ak_sh, bk_sh, diags, na, nb, axis_name, idx)
    b_cuts = jnp.asarray(diags, jnp.int32)[None, :] - a_cuts
    a_shards = [(ak_sh, sent)] + ([(av_sh, jnp.zeros((), av_sh.dtype))] if kv else [])
    b_shards = [(bk_sh, sent)] + ([(bv_sh, jnp.zeros((), bv_sh.dtype))] if kv else [])
    a_wins, alen = _exchange_windows(a_shards, a_cuts, w_a, W_a, p, axis_name, idx)
    b_wins, blen = _exchange_windows(b_shards, b_cuts, w_b, W_b, p, axis_name, idx)
    va = a_wins[1] if kv else None
    vb = b_wins[1] if kv else None
    return _segment_from_windows(a_wins[0], b_wins[0], alen, blen, seg, va, vb)


def _pad_shardable(x: jax.Array, p: int, fill) -> jax.Array:
    """Pad the last axis up to the next multiple of ``p`` with ``fill``."""
    n = x.shape[-1]
    pn = -(-n // p) * p
    if pn == n:
        return x
    pad = jnp.full(x.shape[:-1] + (pn - n,), fill, x.dtype)
    return jnp.concatenate([x, pad], axis=-1)


def _distributed_merge_impl(ak, av, bk, bv, mesh, axis, exchange):
    """Shared wrapper: pad to equal shards, shard_map the merge body, trim.

    Inputs are ``(R, na)`` / ``(R, nb)`` (values may be None); output is
    ``(R, na + nb)`` keys (and values), sharded over the mesh axis.
    """
    if mesh is None:
        mesh = Mesh(jax.devices(), (axis,))
    p = mesh.shape[axis]
    na, nb = ak.shape[-1], bk.shape[-1]
    kd = jnp.result_type(ak, bk)
    ak = ak.astype(kd)
    bk = bk.astype(kd)
    kv = av is not None
    if kv:
        vd = jnp.result_type(av, bv)
        av = av.astype(vd)
        bv = bv.astype(vd)
    if na == 0 or nb == 0:
        keys = bk if na == 0 else ak
        vals = (bv if na == 0 else av) if kv else None
        return keys, vals
    sent = max_sentinel(kd)
    ak = _pad_shardable(ak, p, sent)
    bk = _pad_shardable(bk, p, sent)
    if kv:
        av = _pad_shardable(av, p, jnp.zeros((), av.dtype))
        bv = _pad_shardable(bv, p, jnp.zeros((), bv.dtype))
    body = functools.partial(
        _merge_local_body, axis_name=axis, na=na, nb=nb, p=p, exchange=exchange
    )
    spec = P(None, axis)
    if kv:
        fn = shard_map(
            lambda a, v, b, w: body(a, v, b, w),
            mesh=mesh,
            in_specs=(spec, spec, spec, spec),
            out_specs=(spec, spec),
            check_vma=False,
        )
        keys, vals = fn(ak, av, bk, bv)
        return keys[:, : na + nb], vals[:, : na + nb]
    fn = shard_map(
        lambda a, b: body(a, None, b, None)[0],
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=spec,
        check_vma=False,
    )
    return fn(ak, bk)[:, : na + nb], None


# ---------------------------------------------------------------------------
# guarded dispatch (window -> gather -> core-resort)
# ---------------------------------------------------------------------------

@jax.jit
def _resort_rows(ak, bk):
    """Terminal merge fallback: total-order re-sort of the concatenation
    (stable sort of ``[A; B]`` == the stable A-priority merge)."""
    return merge_sort_batched(jnp.concatenate([ak, bk], axis=-1))


@jax.jit
def _resort_rows_kv(ak, av, bk, bv):
    return merge_sort_kv_batched(
        jnp.concatenate([ak, bk], axis=-1), jnp.concatenate([av, bv], axis=-1)
    )


def _resort_merge(ak, av, bk, bv):
    if av is None:
        return _resort_rows(ak, bk), None
    return _resort_rows_kv(ak, av, bk, bv)


def _record_merge_telemetry(op, ak, bk, mesh, axis, kv):
    """Record the Cor. 7 load-balance metrics for one eager merge.

    Counters: per-device window sizes (``distributed.window_elems.dev*``)
    and accumulated analytic exchange bytes.  Gauges: the per-call
    exchange-byte flavors and ``distributed.balance_ratio`` — max/min of
    the per-device window totals, which Cor. 7 pins to ~1.0 (exactly 1.0
    when ``p | na+nb``; otherwise bounded by the ceil-div remainder).
    The cut table comes from the same Alg. 2 bisection the exchange uses,
    so the recorded windows are the windows that actually moved.
    """
    na, nb = ak.shape[-1], bk.shape[-1]
    if na == 0 or nb == 0:
        return
    p = mesh.shape[axis] if mesh is not None else len(jax.devices())
    rows = ak.shape[0]
    n = na + nb
    info = exchange_bytes(
        na, nb, p, jnp.dtype(jnp.result_type(ak, bk)).itemsize, kv=kv, rows=rows
    )
    tel = _get_telemetry()
    tel.counter("distributed.exchange_calls").add(1)
    for flavor in ("gather", "window_payload", "window_wire_padded"):
        tel.counter(f"distributed.exchange_bytes.{flavor}").add(info[flavor])
        tel.gauge(f"distributed.exchange_bytes.{flavor}").set(info[flavor])
    diags = np.minimum(np.arange(p + 1, dtype=np.int64) * info["seg"], n)
    cuts = np.asarray(
        diagonal_intersections_batched(
            total_order_keys(ak), total_order_keys(bk), jnp.asarray(diags, jnp.int32)
        )
    )
    wa = np.diff(cuts.astype(np.int64), axis=1)  # (rows, p) A-window lengths
    wb = np.diff(diags)[None, :] - wa
    win = (wa + wb).sum(axis=0)
    for d in range(p):
        tel.counter(f"distributed.window_elems.dev{d}").add(int(win[d]))
    nz = win[win > 0]
    ratio = float(nz.max() / nz.min()) if nz.size >= 2 else 1.0
    tel.gauge("distributed.balance_ratio").set(ratio)


def _guarded_merge(op, ak, av, bk, bv, mesh, axis, exchange):
    """Route one distributed merge through the guard.

    Attempt chain: the requested exchange, then ``gather`` (the all-gather
    oracle), then ``core-resort`` — a single-process total-order re-sort of
    the concatenation, which survives even NaN-laced (unsorted) inputs.
    Verification is always on here (tok-space sortedness of the trimmed
    keys): the distributed perf anchor gates exchanged *bytes*, not
    wall-clock, so the host-side check cannot regress CI.  Under tracing
    (the wrappers inside ``jit``/``grad``) the guard bypasses to the
    requested exchange — Python cannot branch on device failures there, and
    a per-device divergent fallback would deadlock the collectives.
    """
    if exchange not in ("window", "gather"):
        raise ValueError(f"exchange must be 'window' or 'gather', got {exchange!r}")
    args = (ak, bk) if av is None else (ak, av, bk, bv)
    if not _res.guard_enabled() or _res.is_tracing(*args):
        return _distributed_merge_impl(ak, av, bk, bv, mesh, axis, exchange)
    idx = _faults.next_index(op)
    if av is None:
        ak, bk = _faults.maybe_nan_lace(op, idx, (ak, bk), (0, 1))
    else:
        ak, av, bk, bv = _faults.maybe_nan_lace(op, idx, (ak, av, bk, bv), (0, 2))

    def run(ex):
        return lambda: _distributed_merge_impl(ak, av, bk, bv, mesh, axis, ex)

    attempts = [("window", run("window"))] if exchange == "window" else []
    attempts.append(("gather", run("gather")))
    attempts.append(("core-resort", lambda: _resort_merge(ak, av, bk, bv)))
    out = _res.guarded_call(
        op, attempts, index=idx, verifier=_res.sorted_verifier(), verify=True
    )
    _record_merge_telemetry(op, ak, bk, mesh, axis, kv=av is not None)
    return out


def distributed_merge(
    a: jax.Array,
    b: jax.Array,
    mesh: Mesh | None = None,
    axis: str = "x",
    exchange: str = "window",
) -> jax.Array:
    """Merge two sorted arrays sharded over a 1-D mesh axis.

    ``exchange="window"`` (default) moves each element once (see the
    module docstring); ``exchange="gather"`` is the all-gather oracle —
    the two are bit-identical.  ``|A|`` and ``|B|`` need not divide evenly
    by the axis size: inputs are sentinel-padded up to the next multiple
    (so each device holds an equal shard), merged length-aware (the pads
    are excluded by count, never by value comparison), and trimmed.

    Eager calls are guarded: a failed or corrupted exchange degrades
    ``window -> gather -> core-resort`` with a :class:`FallbackWarning`
    and health counters (see :mod:`repro.runtime.resilience`).
    """
    keys, _ = _guarded_merge(
        "distributed_merge", a[None, :], None, b[None, :], None, mesh, axis, exchange
    )
    return keys[0]


def distributed_merge_kv(
    ak: jax.Array,
    av: jax.Array,
    bk: jax.Array,
    bv: jax.Array,
    mesh: Mesh | None = None,
    axis: str = "x",
    exchange: str = "window",
) -> Tuple[jax.Array, jax.Array]:
    """Stable key-value merge of two sorted (keys, values) arrays sharded
    over a 1-D mesh axis; values ride the same window exchange as keys.
    Safe for payload keys equal to the sentinel (ranks are length-masked,
    so a shard pad can never shadow a real ``+inf``/``iinfo.max`` key).
    Guarded like :func:`distributed_merge`."""
    if av.shape != ak.shape or bv.shape != bk.shape:
        raise ValueError(
            f"value shapes must match key shapes: keys {ak.shape}/{bk.shape}, "
            f"values {av.shape}/{bv.shape}"
        )
    keys, vals = _guarded_merge(
        "distributed_merge_kv",
        ak[None, :],
        av[None, :],
        bk[None, :],
        bv[None, :],
        mesh,
        axis,
        exchange,
    )
    return keys[0], vals[0]


def distributed_merge_batched(
    a: jax.Array,
    b: jax.Array,
    mesh: Mesh | None = None,
    axis: str = "x",
    exchange: str = "window",
) -> jax.Array:
    """Batched :func:`distributed_merge`: ``(R, na) + (R, nb) -> (R, na+nb)``
    with rows replicated and the merge axis sharded.  Every row has its own
    cut table (the collective bisection carries the batch in its lanes),
    but all rows share the same two all_to_alls.  Guarded like
    :func:`distributed_merge`."""
    keys, _ = _guarded_merge("distributed_merge_batched", a, None, b, None, mesh, axis, exchange)
    return keys


def distributed_merge_kv_batched(
    ak: jax.Array,
    av: jax.Array,
    bk: jax.Array,
    bv: jax.Array,
    mesh: Mesh | None = None,
    axis: str = "x",
    exchange: str = "window",
) -> Tuple[jax.Array, jax.Array]:
    """Batched :func:`distributed_merge_kv` (leading batch axis replicated,
    merge axis sharded) — the vocab-sharded serving building block.
    Guarded like :func:`distributed_merge`."""
    if av.shape != ak.shape or bv.shape != bk.shape:
        raise ValueError(
            f"value shapes must match key shapes: keys {ak.shape}/{bk.shape}, "
            f"values {av.shape}/{bv.shape}"
        )
    return _guarded_merge("distributed_merge_kv_batched", ak, av, bk, bv, mesh, axis, exchange)


def distributed_merge_local(a_shard: jax.Array, b_shard: jax.Array, axis_name: str) -> jax.Array:
    """Per-device all-gather merge body (legacy signature).

    Kept for callers inside their own ``shard_map``: merges
    globally-sharded sorted A and B via one all_gather and returns this
    device's ``N/P`` output slice.  ``|A|`` and ``|B|`` must divide evenly
    by the axis size here; the :func:`distributed_merge` wrapper (which
    also offers the bandwidth-optimal ``exchange="window"`` path) handles
    ragged sizes.
    """
    idx = jax.lax.axis_index(axis_name)
    p = _axis_size(axis_name)
    a = jax.lax.all_gather(a_shard, axis_name, tiled=True)
    b = jax.lax.all_gather(b_shard, axis_name, tiled=True)
    na, nb = a.shape[0], b.shape[0]
    n = na + nb
    seg = n // p
    dtype = jnp.result_type(a, b)
    d0 = idx * seg
    a0 = diagonal_intersections(a, b, d0[None])[0]
    b0 = d0 - a0
    # Window merge: a T-output segment needs at most T from each input
    # (Lemma 16), so slice fixed windows and rank-merge them.
    ap = jnp.concatenate([a.astype(dtype), jnp.full((seg,), max_sentinel(dtype))])
    bp = jnp.concatenate([b.astype(dtype), jnp.full((seg,), max_sentinel(dtype))])
    wa = jax.lax.dynamic_slice(ap, (a0,), (seg,))
    wb = jax.lax.dynamic_slice(bp, (b0,), (seg,))
    valid_a = jnp.clip(na - a0, 0, seg)
    valid_b = jnp.clip(nb - b0, 0, seg)
    ra, rb = _masked_window_ranks(wa, wb, valid_a, valid_b, seg)
    out = jnp.full((seg,), max_sentinel(dtype), dtype)
    out = out.at[ra].set(wa, mode="drop")
    out = out.at[rb].set(wb, mode="drop")
    return out


# ---------------------------------------------------------------------------
# distributed sample sort
# ---------------------------------------------------------------------------

def _pairwise_tree_merge(runs: jax.Array, lens: jax.Array | None = None) -> jax.Array:
    """Deprecated shim: use :func:`repro.core.batched.merge_k` (tournament)
    or :func:`repro.core.batched.merge_k_onepass` (single co-rank pass)
    directly — ``distributed_sort`` now selects between them via its
    ``combine=`` argument, and the distributed merges select their data
    movement via ``exchange=``.  Kept one release for out-of-tree callers.
    """
    return merge_k(runs, lens=lens)


def distributed_sort_local(
    x_shard: jax.Array,
    axis_name: str,
    capacity_factor: float = 2.0,
    local_sort: str = "core",
    combine: str = "onepass",
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-device sample sort body.

    Returns ``(sorted_padded, count, overflowed)``: this device's output
    bucket (ascending, sentinel-padded to the fixed capacity), the number
    of valid elements, and a global overflow flag (any element dropped
    anywhere — callers either assert it is false or retry with a larger
    capacity factor).

    One round of data movement: after the local sort and the (tiny)
    splitter all_gather, every element crosses the mesh exactly once in
    the bucket all_to_all; the per-sender bucket counts ride a second,
    scalar-sized all_to_all (each device needs only the counts *destined
    to it* — gathering the full (P, P) count matrix would be a dead
    round-trip).  The received runs are combined locally:
    ``combine="onepass"`` (default) ranks all P ragged runs in a single
    multiway co-rank pass (:func:`repro.core.batched.merge_k_onepass`);
    ``combine="tournament"`` runs the log2(P)-round pairwise tournament —
    on the Pallas ragged kernel (:func:`repro.kernels.ops.merge_k`) when
    ``local_sort="pallas"``, else :func:`repro.core.batched.merge_k`.

    ``local_sort="pallas"`` runs the per-device sort on the hierarchical
    tile engine (``repro.kernels.ops.sort``, autotuned ``(tile, leaf)``)
    instead of the pure-JAX rounds — the local sort is the compute-bound
    stage of the sample sort, so it is the one worth a kernel.  The tiny
    splitter-candidate sort (``P*P`` elements) stays on the core path.
    """
    p = _axis_size(axis_name)
    m = x_shard.shape[0]
    cap = int(capacity_factor * m)
    # round capacity up so it is lane-aligned
    cap = -(-cap // 128) * 128
    if local_sort == "pallas":
        from repro.kernels import ops as kops  # deferred: kernels layer optional here

        local = kops.sort(x_shard)
    else:
        local = merge_sort(x_shard)
    # P equispaced local samples as splitter candidates
    samp_idx = (jnp.arange(p) * m) // p
    cands = jax.lax.all_gather(local[samp_idx], axis_name, tiled=True)  # (P*P,)
    cands = merge_sort(cands)
    splitters = cands[jnp.arange(1, p) * p]  # P-1 global splitters
    # Bucket k of the (sorted) local shard is the contiguous run
    # [off[k], off[k+1]); offsets by binary search (merge-path diagonal
    # search against the splitter "array").
    offs = jnp.searchsorted(local, splitters, side="left").astype(jnp.int32)
    offs = jnp.concatenate([jnp.zeros(1, jnp.int32), offs, jnp.full((1,), m, jnp.int32)])
    counts = offs[1:] - offs[:-1]  # (P,)
    overflow_local = jnp.any(counts > cap)
    sentinel = max_sentinel(local.dtype)
    lp = jnp.concatenate([local, jnp.full((cap,), sentinel)])

    def take(k):
        return jax.lax.dynamic_slice(lp, (offs[k],), (cap,))

    send = jax.vmap(take)(jnp.arange(p))  # (P, cap) rows sorted
    # mask out elements beyond each bucket's count
    pos = jnp.arange(cap, dtype=jnp.int32)[None, :]
    send = jnp.where(pos < counts[:, None], send, sentinel)
    recv = jax.lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0, tiled=True)
    recv = recv.reshape(p, cap)  # P sorted runs destined for this device
    # Each sender's bucket count for THIS device, by the same all_to_all
    # (counts[k] on sender j is destined to device k): genuinely ragged
    # valid lengths that thread through the combine so sentinel padding
    # can never pollute the valid prefix, even for int payloads
    # containing ``iinfo.max``.
    recv_lens = jax.lax.all_to_all(
        counts, axis_name, split_axis=0, concat_axis=0, tiled=True
    ).astype(jnp.int32)  # (P,)
    if combine == "onepass":
        out = merge_k_onepass(recv, lens=recv_lens)
    elif combine == "tournament":
        if local_sort == "pallas":
            from repro.kernels import ops as kops

            out = kops.merge_k(recv, lens=recv_lens)
        else:
            out = merge_k(recv, lens=recv_lens)
    else:
        raise ValueError(f"combine must be 'onepass' or 'tournament', got {combine!r}")
    count = jnp.sum(recv_lens)
    overflow = jax.lax.pmax(overflow_local.astype(jnp.int32), axis_name) > 0
    return out, count[None], overflow


@jax.jit
def _resort_sort(x):
    """Terminal sample-sort fallback: single-process total-order sort."""
    _, out = merge_sort_kv(total_order_keys(x), x)
    return out


def _dsort_verifier(n: int):
    """Verifier for the sample sort's ``(sorted_padded, counts, overflow)``.

    Rejects when the global overflow flag is set (elements were dropped),
    when the valid counts do not sum to ``n``, or when the concatenation of
    the per-bucket valid prefixes is not globally nondecreasing in
    total-order space.  Comparisons, not diffs (int64 extremes wrap).
    """

    def check(out):
        s, counts, overflow = out
        if bool(np.asarray(overflow)):
            return "bucket overflow (capacity exceeded)"
        counts_np = np.asarray(counts, dtype=np.int64).reshape(-1)
        total = int(counts_np.sum())
        if total != n:
            return f"valid count {total} != n={n}"
        s_np = np.asarray(s)
        p = counts_np.size
        cap = s_np.shape[0] // p
        rows = s_np.reshape(p, cap)
        valid = np.concatenate([rows[i, : counts_np[i]] for i in range(p)])
        if valid.size >= 2:
            tok = np.asarray(total_order_keys(jnp.asarray(valid))).astype(np.int64)
            if not bool(np.all(tok[:-1] <= tok[1:])):
                return "valid prefixes not globally nondecreasing in total-order space"
        return None

    return check


def distributed_sort(
    x: jax.Array,
    mesh: Mesh | None = None,
    axis: str = "x",
    capacity_factor: float = 2.0,
    local_sort: str = "core",
    combine: str = "onepass",
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sample-sort a sharded array; see :func:`distributed_sort_local`.

    Eager calls are guarded: attempt 1 runs the requested configuration;
    a launch failure, a corrupted exchange, or a bucket *overflow* (the
    capacity verifier treats ``overflowed=True`` as a failed attempt)
    escalates to ``capacity-2x`` — the same sort at twice the capacity
    factor — and finally to ``core-resort``, a single-process total-order
    sort (counts shape ``(1,)``, capacity ``n``).  Escalation therefore
    **changes the padded output shape**; callers consuming the guarded
    wrapper must slice by the returned counts rather than assume the
    requested capacity.  Under tracing the requested configuration runs
    unguarded (collective-safe).
    """
    if mesh is None:
        mesh = Mesh(jax.devices(), (axis,))

    def run(cf):
        fn = shard_map(
            functools.partial(
                distributed_sort_local,
                axis_name=axis,
                capacity_factor=cf,
                local_sort=local_sort,
                combine=combine,
            ),
            mesh=mesh,
            in_specs=(P(axis),),
            out_specs=(P(axis), P(axis), P()),
            check_vma=False,
        )
        return fn(x)

    if not _res.guard_enabled() or _res.is_tracing(x):
        return run(capacity_factor)
    idx = _faults.next_index("distributed_sort")
    (x,) = _faults.maybe_nan_lace("distributed_sort", idx, (x,), (0,))
    n = int(x.shape[0])
    attempts = [
        ("sample", lambda: run(capacity_factor)),
        ("capacity-2x", lambda: run(2.0 * capacity_factor)),
        (
            "core-resort",
            lambda: (_resort_sort(x), jnp.full((1,), n, jnp.int32), jnp.zeros((), jnp.bool_)),
        ),
    ]
    return _res.guarded_call(
        "distributed_sort", attempts, index=idx, verifier=_dsort_verifier(n), verify=True
    )


# ---------------------------------------------------------------------------
# distributed top-k
# ---------------------------------------------------------------------------

def _butterfly_topk_combine(lk, lv, k, p, axis_name, idx):
    """log2(P)-round butterfly combine of per-device candidate runs.

    ``lk``/``lv`` are this device's ``(R, k)`` ascending flipped-key runs
    and value rows.  Round ``r`` exchanges candidates with the partner
    ``idx ^ 2^r`` (a static ppermute permutation) and keeps the first
    ``k`` of the pairwise merge — the lower-indexed device of each pair
    is the A side, so the tournament bracket (and hence every tie-break)
    is identical to the gather path's adjacent-pairs tree.  After
    ``log2(P)`` rounds every device holds the replicated global top-k,
    having moved ``k * log2(P)`` candidates instead of gather's ``P * k``.
    """
    rounds = p.bit_length() - 1  # p is a power of two
    for r in range(rounds):
        perm = [(i, i ^ (1 << r)) for i in range(p)]
        ok = jax.lax.ppermute(lk, axis_name, perm)
        ov = jax.lax.ppermute(lv, axis_name, perm)
        am_low = (idx & (1 << r)) == 0
        ak = jnp.where(am_low, lk, ok)
        av = jnp.where(am_low, lv, ov)
        bk = jnp.where(am_low, ok, lk)
        bv = jnp.where(am_low, ov, lv)
        mk, mv = merge_kv_batched(ak, av, bk, bv)
        lk, lv = mk[:, :k], mv[:, :k]
    return lk, lv


def _topk_local_body(x_shard, *, k, axis_name, p, exchange, batched):
    """Per-device body shared by the 1-D and batched distributed top-k."""
    idx = jax.lax.axis_index(axis_name)
    xb = x_shard if batched else x_shard[None, :]
    r, m = xb.shape
    idx0 = (idx * m).astype(jnp.int32)
    lv, li = topk_batched(xb, k)
    li = li.astype(jnp.int32) + idx0
    lk = flip_desc(lv)  # ascending keys; exact for ints at iinfo.min
    if exchange == "butterfly":
        gk, gv = _butterfly_topk_combine(lk, li, k, p, axis_name, idx)
    elif exchange == "gather":
        # gather candidate runs; merge on order-flipped keys so ascending
        # merge = descending values.  Pad value slots (pow2 rounds inside
        # merge_k_kv) are excluded by LENGTH, so no pad index can surface.
        keys = jax.lax.all_gather(lk, axis_name, tiled=False)  # (P, R, k)
        idxs = jax.lax.all_gather(li, axis_name, tiled=False)

        def combine_row(kr, vr):  # (P, k) runs for one batch row
            mk, mv = merge_k_kv(kr, vr)
            return mk[:k], mv[:k]

        gk, gv = jax.vmap(combine_row, in_axes=1, out_axes=0)(keys, idxs)
    else:
        raise ValueError(f"exchange must be 'butterfly' or 'gather', got {exchange!r}")
    vals = flip_desc(gk)
    return (vals, gv) if batched else (vals[0], gv[0])


def _resolve_topk_exchange(exchange: str, p: int) -> str:
    if exchange == "auto":
        return "butterfly" if p >= 2 and (p & (p - 1)) == 0 else "gather"
    if exchange == "butterfly" and (p < 2 or (p & (p - 1)) != 0):
        raise ValueError(f"butterfly combine needs a power-of-two axis, got P={p}")
    return exchange


def distributed_topk_local(
    x_shard: jax.Array, k: int, axis_name: str
) -> Tuple[jax.Array, jax.Array]:
    """Per-device body (legacy signature): global (values, indices) top-k of
    a sharded vector via the gather combine.  Indices are global; the
    result is replicated across the axis.  The :func:`distributed_topk`
    wrapper additionally offers the bandwidth-lean butterfly combine."""
    p = _axis_size(axis_name)
    return _topk_local_body(
        x_shard, k=k, axis_name=axis_name, p=p, exchange="gather", batched=False
    )


def distributed_topk(
    x: jax.Array,
    k: int,
    mesh: Mesh | None = None,
    axis: str = "x",
    exchange: str = "auto",
) -> Tuple[jax.Array, jax.Array]:
    """Global (values, indices) top-k of a sharded vector, replicated.

    ``exchange="auto"`` picks the log2(P)-round butterfly combine
    (``k * log2(P)`` candidates moved per device) when the axis size is a
    power of two, else the all_gather tree (``P * k`` per device).  Both
    are bit-identical — same bracket, same tie-breaks.

    Eager calls are guarded: a failed butterfly degrades to ``gather``,
    and both degrade to ``core-topk`` — the single-process batched
    merge-path top-k, which is NaN-exact via the total-order key route.
    """
    if mesh is None:
        mesh = Mesh(jax.devices(), (axis,))
    p = mesh.shape[axis]
    exchange = _resolve_topk_exchange(exchange, p)

    def run(ex):
        fn = shard_map(
            functools.partial(
                _topk_local_body, k=k, axis_name=axis, p=p, exchange=ex, batched=False
            ),
            mesh=mesh,
            in_specs=(P(axis),),
            out_specs=(P(), P()),
            check_vma=False,
        )
        return fn(x)

    if not _res.guard_enabled() or _res.is_tracing(x):
        return run(exchange)
    idx = _faults.next_index("distributed_topk")
    (x,) = _faults.maybe_nan_lace("distributed_topk", idx, (x,), (0,))

    def core():
        v, i = topk_batched(x[None, :], k)
        return v[0], i[0].astype(jnp.int32)

    attempts = [(exchange, lambda: run(exchange))]
    if exchange != "gather":
        attempts.append(("gather", lambda: run("gather")))
    attempts.append(("core-topk", core))
    return _res.guarded_call(
        "distributed_topk", attempts, index=idx, verifier=_res.topk_verifier(), verify=True
    )


def distributed_topk_batched(
    x: jax.Array,
    k: int,
    mesh: Mesh | None = None,
    axis: str = "x",
    exchange: str = "auto",
) -> Tuple[jax.Array, jax.Array]:
    """Row-wise global top-k of ``(R, V)`` logits sharded over the vocab.

    The vocab-sharded serving primitive: every row's shard-local top-k
    candidates ride one combine (butterfly or gather, like
    :func:`distributed_topk`), and the replicated ``(R, k)`` result feeds
    the samplers directly (``repro.serving.sampler`` ``backend=
    "distributed"``).  Indices are global vocab ids; tie-breaking matches
    ``jax.lax.top_k`` (smallest index first).  Guarded like
    :func:`distributed_topk`.
    """
    if mesh is None:
        mesh = Mesh(jax.devices(), (axis,))
    p = mesh.shape[axis]
    exchange = _resolve_topk_exchange(exchange, p)

    def run(ex):
        fn = shard_map(
            functools.partial(
                _topk_local_body, k=k, axis_name=axis, p=p, exchange=ex, batched=True
            ),
            mesh=mesh,
            in_specs=(P(None, axis),),
            out_specs=(P(), P()),
            check_vma=False,
        )
        return fn(x)

    if not _res.guard_enabled() or _res.is_tracing(x):
        return run(exchange)
    idx = _faults.next_index("distributed_topk_batched")
    (x,) = _faults.maybe_nan_lace("distributed_topk_batched", idx, (x,), (0,))

    def core():
        v, i = topk_batched(x, k)
        return v, i.astype(jnp.int32)

    attempts = [(exchange, lambda: run(exchange))]
    if exchange != "gather":
        attempts.append(("gather", lambda: run("gather")))
    attempts.append(("core-topk", core))
    return _res.guarded_call(
        "distributed_topk_batched",
        attempts,
        index=idx,
        verifier=_res.topk_verifier(),
        verify=True,
    )
