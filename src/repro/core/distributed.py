"""Distributed Merge Path — the paper's algorithm lifted to a device mesh.

The paper partitions one merge across p cores sharing a cache; here the
"cores" are TPU chips sharing an ICI and the partition math is identical.
Three primitives, each in two forms: a ``*_local`` body (runs inside
``shard_map``, uses ``jax.lax`` collectives over a named axis) and a
convenience wrapper that builds a 1-D mesh over all visible devices.

* ``distributed_merge``: A and B sharded contiguously over the axis; each
  device computes exactly its 1/P slice of the output after one
  all_gather.  Compute is perfectly balanced by Corollary 7; the gather is
  the (bandwidth-suboptimal, latency-optimal) Megatron-style choice — the
  bandwidth-optimal alternative is the sample sort below, which moves each
  element once via all_to_all.
* ``distributed_sort``: sample sort with merge-path local sorts and a
  log(P)-round merge-path combine.  This is the paper's parallel
  merge-sort with the shared cache replaced by explicit collectives.
* ``distributed_topk``: per-shard merge-path top-k, all_gather of the P
  sorted candidate runs, merge-path combine.  Used for vocab-sharded
  sampling in serving.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.6: top-level export, replication check renamed to check_vma
    from jax import shard_map as _shard_map_impl

    _CHECK_KW = "check_vma"
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
    """Version-portable ``shard_map``: accepts either replication-check
    kwarg name and translates to the installed jax's spelling.  Defaults
    the check off (this repo's bodies use untyped collectives), but an
    explicit ``check_vma=True`` / ``check_rep=True`` is honored."""
    check = kwargs.pop("check_vma", None)
    if check is None:
        check = kwargs.pop("check_rep", None)
    else:
        kwargs.pop("check_rep", None)
    kwargs[_CHECK_KW] = False if check is None else check
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def _axis_size(axis_name: str) -> int:
    """``jax.lax.axis_size`` compat (added after 0.4.x)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)

from .batched import merge_k
from .merge_path import (
    diagonal_intersections,
    flip_desc,
    max_sentinel,
    merge_sort,
    topk_desc,
)
from .segmented import _masked_window_ranks


# ---------------------------------------------------------------------------
# distributed merge
# ---------------------------------------------------------------------------

def distributed_merge_local(a_shard: jax.Array, b_shard: jax.Array, axis_name: str) -> jax.Array:
    """Per-device body: merge globally-sharded sorted A and B.

    Each device all_gathers A and B (one collective), finds its segment's
    (a_start, b_start) by the cross-diagonal binary search on its own rank's
    equispaced diagonal, and merges exactly ``N/P`` outputs.  Writes are
    disjoint by Lemma 3 — the returned shard *is* this device's slice of S.

    Window ranks are length-masked (:func:`repro.core.segmented._masked_window_ranks`),
    so sentinel-valued payloads merge exactly — required by the padded
    wrapper below, whose pads would otherwise shadow them.
    """
    idx = jax.lax.axis_index(axis_name)
    p = _axis_size(axis_name)
    a = jax.lax.all_gather(a_shard, axis_name, tiled=True)
    b = jax.lax.all_gather(b_shard, axis_name, tiled=True)
    na, nb = a.shape[0], b.shape[0]
    n = na + nb
    seg = n // p
    dtype = jnp.result_type(a, b)
    d0 = idx * seg
    a0 = diagonal_intersections(a, b, d0[None])[0]
    b0 = d0 - a0
    # Window merge: a T-output segment needs at most T from each input
    # (Lemma 16), so slice fixed windows and rank-merge them.
    ap = jnp.concatenate([a.astype(dtype), jnp.full((seg,), max_sentinel(dtype))])
    bp = jnp.concatenate([b.astype(dtype), jnp.full((seg,), max_sentinel(dtype))])
    wa = jax.lax.dynamic_slice(ap, (a0,), (seg,))
    wb = jax.lax.dynamic_slice(bp, (b0,), (seg,))
    valid_a = jnp.clip(na - a0, 0, seg)
    valid_b = jnp.clip(nb - b0, 0, seg)
    ra, rb = _masked_window_ranks(wa, wb, valid_a, valid_b, seg)
    out = jnp.full((seg,), max_sentinel(dtype), dtype)
    out = out.at[ra].set(wa, mode="drop")
    out = out.at[rb].set(wb, mode="drop")
    return out


def distributed_merge(a: jax.Array, b: jax.Array, mesh: Mesh | None = None, axis: str = "x") -> jax.Array:
    """Merge two sorted arrays sharded over a 1-D mesh axis.

    ``|A|`` and ``|B|`` need not divide evenly by the axis size: inputs
    are sentinel-padded up to the next multiple (so each device holds an
    equal shard), merged, and the padding — which stability keeps after
    every real element — is trimmed off the gathered result.
    """
    if mesh is None:
        mesh = Mesh(jax.devices(), (axis,))
    p = mesh.shape[axis]
    na, nb = a.shape[0], b.shape[0]
    pa = -(-na // p) * p
    pb = -(-nb // p) * p
    dtype = jnp.result_type(a, b)
    if pa != na:
        a = jnp.concatenate([a.astype(dtype), jnp.full((pa - na,), max_sentinel(dtype))])
    if pb != nb:
        b = jnp.concatenate([b.astype(dtype), jnp.full((pb - nb,), max_sentinel(dtype))])
    fn = shard_map(
        functools.partial(distributed_merge_local, axis_name=axis),
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=P(axis),
        check_vma=False,
    )
    return fn(a, b)[: na + nb]


# ---------------------------------------------------------------------------
# distributed sample sort
# ---------------------------------------------------------------------------

def _pairwise_tree_merge(runs: jax.Array, lens: jax.Array | None = None) -> jax.Array:
    """Merge (R, L) sorted rows into one sorted (R*L,) array, log2(R) rounds.

    Thin alias of :func:`repro.core.batched.merge_k`, kept for the
    distributed bodies.  ``lens`` optionally gives each row's valid
    length; without it every row counts in full.  Tie-break: stable with
    lower-row priority (ties resolve toward the lower-indexed run, and
    within a run original order is kept).  Because ``merge_k`` threads
    valid lengths through every round instead of trusting sentinel
    comparisons, int runs whose *data* contains ``iinfo.max`` (or float
    runs containing ``+inf``) merge exactly — the valid prefix of the
    result is never polluted by padding.
    """
    return merge_k(runs, lens=lens)


def distributed_sort_local(
    x_shard: jax.Array,
    axis_name: str,
    capacity_factor: float = 2.0,
    local_sort: str = "core",
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-device sample sort body.

    Returns ``(sorted_padded, count, overflowed)``: this device's output
    bucket (ascending, sentinel-padded to the fixed capacity), the number
    of valid elements, and a global overflow flag (any element dropped
    anywhere — callers either assert it is false or retry with a larger
    capacity factor).

    ``local_sort="pallas"`` runs the per-device sort on the hierarchical
    tile engine (``repro.kernels.ops.sort``, autotuned ``(tile, leaf)``)
    instead of the pure-JAX rounds — the local sort is the compute-bound
    stage of the sample sort, so it is the one worth a kernel.  The tiny
    splitter-candidate sort (``P*P`` elements) stays on the core path.
    """
    p = _axis_size(axis_name)
    m = x_shard.shape[0]
    cap = int(capacity_factor * m)
    # round capacity up so it is lane-aligned
    cap = -(-cap // 128) * 128
    if local_sort == "pallas":
        from repro.kernels import ops as kops  # deferred: kernels layer optional here

        local = kops.sort(x_shard)
    else:
        local = merge_sort(x_shard)
    # P equispaced local samples as splitter candidates
    samp_idx = (jnp.arange(p) * m) // p
    cands = jax.lax.all_gather(local[samp_idx], axis_name, tiled=True)  # (P*P,)
    cands = merge_sort(cands)
    splitters = cands[jnp.arange(1, p) * p]  # P-1 global splitters
    # Bucket k of the (sorted) local shard is the contiguous run
    # [off[k], off[k+1]); offsets by binary search (merge-path diagonal
    # search against the splitter "array").
    offs = jnp.searchsorted(local, splitters, side="left").astype(jnp.int32)
    offs = jnp.concatenate([jnp.zeros(1, jnp.int32), offs, jnp.full((1,), m, jnp.int32)])
    counts = offs[1:] - offs[:-1]  # (P,)
    overflow_local = jnp.any(counts > cap)
    sentinel = max_sentinel(local.dtype)
    lp = jnp.concatenate([local, jnp.full((cap,), sentinel)])

    def take(k):
        return jax.lax.dynamic_slice(lp, (offs[k],), (cap,))

    send = jax.vmap(take)(jnp.arange(p))  # (P, cap) rows sorted
    # mask out elements beyond each bucket's count
    pos = jnp.arange(cap, dtype=jnp.int32)[None, :]
    send = jnp.where(pos < counts[:, None], send, sentinel)
    recv = jax.lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0, tiled=True)
    recv = recv.reshape(p, cap)  # P sorted runs destined for this device
    idx = jax.lax.axis_index(axis_name)
    # (P, P) count matrix: row = sender, col = destination bucket.  This
    # device's P received runs have the genuinely *ragged* valid lengths
    # counts_mat[:, idx] (each sender fills its bucket differently), so the
    # combine is a ragged k-way merge — lengths thread through every round
    # and the sentinel padding can never pollute the valid prefix, even
    # for int payloads containing ``iinfo.max``.
    counts_mat = jax.lax.all_gather(counts, axis_name, tiled=False)
    recv_lens = counts_mat[:, idx].astype(jnp.int32)
    out = _pairwise_tree_merge(recv, lens=recv_lens)  # (P*cap,) ascending, sentinels last
    count = jnp.sum(counts_mat, axis=0)[idx]
    overflow = jax.lax.pmax(overflow_local.astype(jnp.int32), axis_name) > 0
    return out, count[None], overflow


def distributed_sort(
    x: jax.Array,
    mesh: Mesh | None = None,
    axis: str = "x",
    capacity_factor: float = 2.0,
    local_sort: str = "core",
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sample-sort a sharded array; see :func:`distributed_sort_local`."""
    if mesh is None:
        mesh = Mesh(jax.devices(), (axis,))
    fn = shard_map(
        functools.partial(
            distributed_sort_local,
            axis_name=axis,
            capacity_factor=capacity_factor,
            local_sort=local_sort,
        ),
        mesh=mesh,
        in_specs=(P(axis),),
        out_specs=(P(axis), P(axis), P()),
        check_vma=False,
    )
    return fn(x)


# ---------------------------------------------------------------------------
# distributed top-k
# ---------------------------------------------------------------------------

def distributed_topk_local(
    x_shard: jax.Array, k: int, axis_name: str
) -> Tuple[jax.Array, jax.Array]:
    """Per-device body: global (values, indices) top-k of a sharded vector.

    Local merge-path top-k, then an all_gather of the P sorted candidate
    runs (P*k elements — tiny), then a merge-path tree combine.  Indices
    are global.  Result is replicated across the axis.
    """
    p = _axis_size(axis_name)
    m = x_shard.shape[0]
    idx0 = jax.lax.axis_index(axis_name) * m
    lv, li = topk_desc(x_shard, k)
    li = li.astype(jnp.int32) + idx0
    # gather candidate runs; merge on order-flipped keys so ascending merge
    # = descending values.  flip_desc (an involution: ~~x == x, -(-x) == x)
    # instead of negation, which wraps int candidates equal to iinfo.min.
    keys = jax.lax.all_gather(flip_desc(lv), axis_name, tiled=False)  # (P, k) each ascending
    idxs = jax.lax.all_gather(li, axis_name, tiled=False)  # (P, k)
    # tree merge of kv runs
    from .merge_path import merge_kv

    runs_k, runs_v = keys, idxs
    r = runs_k.shape[0]
    target = 1 << max(0, (r - 1).bit_length())
    if target != r:
        # Pad rows carry sentinel keys (+inf) that *tie* with real +inf
        # keys (the negated -inf logits).  Their value slots are -1 — an
        # impossible global index — so a pad that ever survived a merge
        # round is detectable instead of masquerading as vocab index 0.
        # With k <= n_valid the A-priority tie-break (real runs are
        # always the lower-indexed A side of their round) keeps every
        # real candidate ahead of the pads, so no -1 can surface; tests
        # assert this under all--inf logits.
        runs_k = jnp.concatenate(
            [runs_k, jnp.full((target - r, k), max_sentinel(runs_k.dtype))], axis=0
        )
        runs_v = jnp.concatenate(
            [runs_v, jnp.full((target - r, k), -1, runs_v.dtype)], axis=0
        )
    while runs_k.shape[0] > 1:
        mk, mv = jax.vmap(merge_kv)(runs_k[0::2], runs_v[0::2], runs_k[1::2], runs_v[1::2])
        # only the first k of every merged run can survive to the global top-k
        runs_k, runs_v = mk[:, :k], mv[:, :k]
    return flip_desc(runs_k[0]), runs_v[0]


def distributed_topk(
    x: jax.Array, k: int, mesh: Mesh | None = None, axis: str = "x"
) -> Tuple[jax.Array, jax.Array]:
    if mesh is None:
        mesh = Mesh(jax.devices(), (axis,))
    fn = shard_map(
        functools.partial(distributed_topk_local, k=k, axis_name=axis),
        mesh=mesh,
        in_specs=(P(axis),),
        out_specs=(P(), P()),
        check_vma=False,
    )
    return fn(x)
