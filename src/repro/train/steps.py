"""train_step / serve-step builders: loss, grads, optimizer, sharding glue.

``make_train_step(cfg, tcfg)`` returns a pure ``(state, batch) -> (state,
metrics)`` function suitable for ``jax.jit`` with in/out shardings from
``parallel.sharding``; the dry-run lowers exactly this function.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import forward_train, forward_decode, forward_prefill, init_params
from repro.optim.adamw import adamw_update, init_opt_state
from repro.parallel import compression
from repro.parallel.sharding import constrain


def cross_entropy_loss(
    logits: jax.Array, labels: jax.Array, z_loss: float
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Mean token CE (fp32) + z-loss; labels < 0 are masked."""
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    labels_c = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
    ce = (lse - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(ce) / denom
    zl = z_loss * jnp.sum(jnp.square(lse) * mask) / denom
    metrics = {"ce": loss, "z_loss": zl}
    return loss + zl, metrics


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, key: jax.Array) -> Dict[str, Any]:
    params = init_params(cfg, key)
    # fp32 masters
    params = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "params": params,
        "opt": init_opt_state(params),
    }
    if tcfg.grad_compression != "none":
        state["err"] = compression.init_error_state(params)
    return state


def abstract_train_state(cfg: ModelConfig, tcfg: TrainConfig):
    return jax.eval_shape(lambda k: init_train_state(cfg, tcfg, k), jax.random.key(0))


def _cast(params, dtype):
    return jax.tree.map(
        lambda p: p.astype(dtype) if p.dtype == jnp.float32 and p.ndim > 1 else p, params
    )


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    compute_dtype = jnp.dtype(cfg.dtype)

    def loss_fn(params, batch):
        logits = forward_train(cfg, _cast(params, compute_dtype), batch)
        return cross_entropy_loss(logits, batch["labels"], tcfg.z_loss)

    def grads_of(params, batch):
        if tcfg.microbatch and tcfg.microbatch > 1:
            nm = tcfg.microbatch
            b = batch["tokens"].shape[0]
            assert b % nm == 0, f"batch {b} % microbatch {nm} != 0"
            mb = jax.tree.map(lambda t: t.reshape(nm, b // nm, *t.shape[1:]), batch)

            def acc_step(carry, mbatch):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mbatch)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            from repro.utils.costmode import scan_unroll

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g, loss), _ = jax.lax.scan(acc_step, (g0, 0.0), mb, unroll=scan_unroll(nm))
            g = jax.tree.map(lambda t: t / nm, g)
            return loss / nm, {}, g
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, metrics, g

    def train_step(state: Dict[str, Any], batch: Dict[str, jax.Array]):
        loss, metrics, grads = grads_of(state["params"], batch)
        new_state = dict(state)
        if tcfg.grad_compression != "none":
            grads, new_err = compression.compress_grads(
                grads, state["err"], tcfg.grad_compression, tcfg.compression_topk
            )
            new_state["err"] = new_err
        params, opt, opt_metrics = adamw_update(
            tcfg, state["params"], grads, state["opt"], state["step"]
        )
        new_state.update(step=state["step"] + 1, params=params, opt=opt)
        out_metrics = {"loss": loss, **metrics, **opt_metrics}
        return new_state, out_metrics

    return train_step


# ---------------------------------------------------------------------------
# serve steps (dry-run entry points; the full engine lives in serving/)
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig):
    compute_dtype = jnp.dtype(cfg.dtype)

    def prefill_step(params, batch):
        logits, caches, enc_kv = forward_prefill(cfg, _cast(params, compute_dtype), batch)
        return logits, caches

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    compute_dtype = jnp.dtype(cfg.dtype)

    def decode_step(params, caches, token, pos, enc_kv=None):
        logits, new_caches = forward_decode(
            cfg, _cast(params, compute_dtype), caches, token, pos, enc_kv=enc_kv
        )
        return logits, new_caches

    return decode_step
