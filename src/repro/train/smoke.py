"""Kernel-path training smoke test (``make train-smoke``).

Runs one **real** :func:`repro.train.steps.make_train_step` step — loss,
backward, AdamW update — for two reduced-but-faithful configs that route
training through the Pallas kernels:

* falcon-mamba (SSM family) with ``ssm_backend="fused"`` — the forward
  AND backward go through ``repro.kernels.ssm_scan``'s chunk-recompute
  ``custom_vjp``;
* phi3.5-moe (MoE family) with ``moe_dispatch="merge_path_pallas"`` —
  dispatch positions come from the hierarchical tile-engine kv-sort in
  ``repro.kernels.ops`` (seq is sized so the flat round actually exceeds
  the minimum Pallas tile and the kernel, not the XLA fallback, runs).

For each config it asserts:

1. the step's loss is finite;
2. ``jax.grad`` of the *same* loss function produces a finite, nonzero
   gradient on **every** parameter leaf (a dead leaf means a route
   silently detached — exactly the failure mode the custom VJPs close);
3. the optimizer update actually moved the parameters.

Interpret-mode Pallas (the default off-TPU) makes this CPU-runnable; on
real hardware the same script exercises the compiled kernels.
"""

from __future__ import annotations

import dataclasses
import sys

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.train.steps import init_train_state, make_train_step


def _fake_batch(key: jax.Array, batch: int, seq: int, vocab: int):
    tok = jax.random.randint(key, (batch, seq), 0, vocab, jnp.int32)
    labels = jnp.roll(tok, -1, axis=1).at[:, -1].set(-1)  # mask last position
    return {"tokens": tok, "labels": labels}


def _leaf_report(grads) -> list:
    """(path, finite, nonzero) per leaf."""
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(grads)[0]:
        name = jax.tree_util.keystr(path)
        finite = bool(jnp.all(jnp.isfinite(leaf)))
        nonzero = bool(jnp.any(leaf != 0))
        out.append((name, finite, nonzero))
    return out


def smoke_one(name: str, cfg, *, batch: int, seq: int, seed: int = 0) -> bool:
    from repro.configs.base import TrainConfig

    tcfg = TrainConfig(z_loss=1e-4, grad_clip=1.0)
    key = jax.random.PRNGKey(seed)
    kinit, kbatch = jax.random.split(key)
    state = init_train_state(cfg, tcfg, kinit)
    # the linear warmup is exactly 0 at step 0; start mid-warmup so a
    # zero-lr first step can't mask a dead backward
    state["step"] = jnp.ones((), jnp.int32)
    data = _fake_batch(kbatch, batch, seq, cfg.vocab_size)

    # per-leaf gradient audit against the identical loss the step uses
    step = make_train_step(cfg, tcfg)
    from repro.train.steps import _cast, cross_entropy_loss
    from repro.models import forward_train

    def loss_fn(params):
        logits = forward_train(cfg, _cast(params, jnp.dtype(cfg.dtype)), data)
        return cross_entropy_loss(logits, data["labels"], tcfg.z_loss)[0]

    loss0, grads = jax.value_and_grad(loss_fn)(state["params"])
    report = _leaf_report(grads)
    bad = [(n, f, z) for n, f, z in report if not (f and z)]

    new_state, metrics = jax.jit(step)(state, data)
    loss = float(metrics["loss"])
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(state["params"]), jax.tree.leaves(new_state["params"]))
    )

    ok = jnp.isfinite(loss0) and jnp.isfinite(loss) and not bad and moved
    status = "ok" if ok else "FAIL"
    print(
        f"[train-smoke] {name}: loss={loss:.4f} leaves={len(report)} "
        f"all_finite_nonzero={not bad} params_moved={moved} -> {status}"
    )
    for n, f, z in bad:
        print(f"  BAD LEAF {n}: finite={f} nonzero={z}")
    return bool(ok)


def main() -> int:
    ok = True

    # SSM on the fused Pallas scan (falcon-mamba-shaped). Seq straddles
    # chunk boundaries (not a multiple of ssm_chunk=8) so the identity-pad
    # path of the kernel is part of the trained graph.
    ssm = dataclasses.replace(get_config("falcon-mamba").reduced(), ssm_backend="fused")
    ok &= smoke_one("falcon-mamba/fused-ssm-scan", ssm, batch=2, seq=36)

    # MoE on the tile-engine dispatch (phi3.5-moe-shaped). seq*k = 512
    # assignment slots > the minimum int sort tile (256), so the flat
    # merge round runs in the Pallas kernel, not the small-n fallback.
    moe = dataclasses.replace(
        get_config("phi35-moe").reduced(), moe_dispatch="merge_path_pallas"
    )
    ok &= smoke_one("phi3.5-moe/merge-path-pallas", moe, batch=1, seq=256)

    if not ok:
        print("[train-smoke] FAILED", file=sys.stderr)
        return 1
    print("[train-smoke] all kernel-path train steps passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
