"""Gradient compression for the cross-pod all-reduce.

At 2+ pods the pod-level gradient reduction crosses the (slow) DCI, so
the framework offers two compressors, applied leaf-wise before the pod
all-reduce, with the residual kept locally (error feedback) so the
compression is unbiased over time:

* ``topk``: keep the top f-fraction of |g| entries (selected with the
  merge-path top-k — the paper's technique again), zero the rest, and
  add the zeroed part to a persistent error buffer that is re-injected
  next step.
* ``int8``: per-leaf symmetric int8 quantization (scale = max|g|/127),
  residual also fed back.

These run *inside* jit; the all-reduce itself is whatever XLA emits for
the psum over the ``pod`` axis.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import topk_desc


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_leaf(g: jax.Array, err: jax.Array, frac: float):
    g = g.astype(jnp.float32) + err
    flat = g.reshape(-1)
    k = max(1, int(frac * flat.shape[0]))
    if flat.shape[0] <= 65536:
        # merge-path top-k on |g| gives the exact threshold
        vals, _ = topk_desc(jnp.abs(flat), k)
        thresh = vals[-1]
    else:
        # large leaves: quantile threshold (XLA sort) — same mask semantics
        thresh = jnp.quantile(jnp.abs(flat), 1.0 - frac)
    mask = (jnp.abs(flat) >= thresh).astype(jnp.float32)
    kept = flat * mask
    new_err = (flat - kept).reshape(g.shape)
    return kept.reshape(g.shape), new_err


def _int8_leaf(g: jax.Array, err: jax.Array):
    g = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, g - deq


def compress_grads(
    grads, err_state, method: str, topk_frac: float
) -> Tuple[Any, Any]:
    """Returns (compressed_grads, new_error_state)."""
    if method == "none":
        return grads, err_state
    fn = (lambda g, e: _topk_leaf(g, e, topk_frac)) if method == "topk" else _int8_leaf
    out = jax.tree.map(fn, grads, err_state)
    comp = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return comp, err
