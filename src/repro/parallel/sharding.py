"""Sharding rules: logical activation/param axes -> mesh axes.

Parallelism layout (see DESIGN.md §6):

* batch        -> (pod, data)   (pod axis only on the multi-pod mesh)
* FSDP         -> data          (or (pod, data) when cfg.fsdp_over_pod —
                                 nemotron-340B's optimizer state needs it)
* tensor       -> model         (heads / ff / vocab / experts / d_inner)
* context      -> model         (long-context decode KV cache sequence dim)

Activations are annotated through :func:`constrain`, a no-op unless a
:class:`ShardingEnv` is active — smoke tests run the exact same model
code with no mesh at all.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _canon_entry(mesh_axes):
    """Canonical PartitionSpec entry: () -> None, ("x",) -> "x".

    Newer jax normalizes singleton tuples inside PartitionSpec itself;
    0.4.x keeps them verbatim, so normalize here for version-stable specs.
    """
    if not mesh_axes:
        return None
    if isinstance(mesh_axes, tuple) and len(mesh_axes) == 1:
        return mesh_axes[0]
    return mesh_axes


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """Logical-axis -> mesh-axis mapping."""

    batch: Tuple[str, ...] = ("data",)
    fsdp: Tuple[str, ...] = ("data",)
    tensor: Tuple[str, ...] = ("model",)
    context: Tuple[str, ...] = ()  # set to ("model",) for context-parallel decode

    def spec(self, *logical: Optional[str]) -> P:
        out = []
        for ax in logical:
            if ax is None:
                out.append(None)
            else:
                mesh_axes = getattr(self, ax)
                out.append(_canon_entry(mesh_axes))
        return P(*out)


# Logical names used by model code for activations:
#   act_batch, act_seq, act_heads, act_ff, act_vocab, act_embed, act_experts, act_kv_seq
_ACT_AXIS = {
    "act_batch": "batch",
    "act_seq": None,
    "act_kv_seq": "context",
    "act_heads": "tensor",
    "act_ff": "tensor",
    "act_vocab": "tensor",
    "act_embed": None,
    "act_experts": "tensor",
    "none": None,
}


@dataclasses.dataclass
class ShardingEnv:
    mesh: Mesh
    rules: MeshRules


_STATE = threading.local()


def current_env() -> Optional[ShardingEnv]:
    return getattr(_STATE, "env", None)


@contextlib.contextmanager
def sharding_env(mesh: Mesh, rules: MeshRules):
    prev = current_env()
    _STATE.env = ShardingEnv(mesh, rules)
    try:
        yield _STATE.env
    finally:
        _STATE.env = prev


def constrain(x: jax.Array, *act_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint on logical activation axes (no-op w/o env)."""
    env = current_env()
    if env is None:
        return x
    logical = [_ACT_AXIS.get(a) if a is not None else None for a in act_axes]
    spec = env.rules.spec(*logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(env.mesh, spec))


def make_rules(mesh: Mesh, fsdp_over_pod: bool = False, context_parallel: bool = False) -> MeshRules:
    axes = mesh.axis_names
    multi_pod = "pod" in axes
    batch = ("pod", "data") if multi_pod else ("data",)
    fsdp = (("pod", "data") if (multi_pod and fsdp_over_pod) else ("data",))
    return MeshRules(
        batch=batch,
        fsdp=fsdp,
        tensor=("model",),
        context=("model",) if context_parallel else (),
    )


# ---------------------------------------------------------------------------
# Parameter sharding: leaf-name pattern -> logical axes per dim (trailing dims)
# ---------------------------------------------------------------------------

# name -> logical axes for the *trailing* dims of the leaf (leading stacked
# layer dims get None automatically).
_PARAM_RULES = {
    # embeddings
    "table": ("tensor", "fsdp"),  # (V, d)
    "unembed": ("fsdp", "tensor"),  # (d, V)
    "prefix_proj": ("fsdp", None),  # (d_in, d)
    "pos_embed": (None, "fsdp"),  # (S, d)
    # attention
    "wq": ("fsdp", "tensor"),
    "wk": ("fsdp", "tensor"),
    "wv": ("fsdp", "tensor"),
    "wo": ("tensor", "fsdp"),
    "xq": ("fsdp", "tensor"),
    "xk": ("fsdp", "tensor"),
    "xv": ("fsdp", "tensor"),
    "xo": ("tensor", "fsdp"),
    # dense mlp
    "wi": ("fsdp", "tensor"),
    "wg": ("fsdp", "tensor"),
    # moe (leaves live under 'moe' and get expert-leading rules below)
    "router": ("fsdp", None),  # (d, E)
    # mamba
    "in_proj": ("fsdp", "tensor"),  # (d, 2*di)
    "conv_w": ("tensor", None),  # (di, k)
    "x_proj": ("tensor", None),  # (di, r+2s)
    "dt_proj": (None, "tensor"),  # (r, di)
    "dt_bias": ("tensor",),  # (di,)
    "A_log": ("tensor", None),  # (di, s)
    "D": ("tensor",),  # (di,)
    "out_proj": ("tensor", "fsdp"),  # (di, d)
}

_MOE_RULES = {
    # (E, d, ff_e) / (E, ff_e, d): experts over tensor, d over fsdp
    "wi": ("tensor", "fsdp", None),
    "wg": ("tensor", "fsdp", None),
    "wo": ("tensor", None, "fsdp"),
    "router": ("fsdp", None),
}


def param_spec(path: Tuple, leaf) -> P:
    """PartitionSpec for one parameter leaf given its tree path."""
    keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
    name = keys[-1]
    in_moe = "moe" in keys and "shared" not in keys
    rules = _MOE_RULES if in_moe else _PARAM_RULES
    logical = rules.get(name)
    if logical is None:
        if name in ("scale", "attn_norm", "ffn_norm", "cross_norm", "final_norm", "norm"):
            logical = (None,) * 1
        else:
            logical = ()
    ndim = leaf.ndim
    pad = ndim - len(logical)
    if pad < 0:  # leaf smaller than rule (e.g. reduced config squeezed) — replicate
        return P()
    return tuple([None] * pad + list(logical)), name


def param_pspec_tree(params, rules: MeshRules):
    """Tree of PartitionSpec matching ``params``."""

    def one(path, leaf):
        logical, _ = param_spec(path, leaf)
        # map logical to mesh axes
        axes = []
        for ax in logical:
            if ax is None:
                axes.append(None)
            else:
                axes.append(_canon_entry(getattr(rules, ax)))
        return P(*axes)

    return jax.tree_util.tree_map_with_path(one, params)


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on dims not divisible by the mesh-axis degree.

    jit in_shardings require exact divisibility (unlike constraint
    annotations); e.g. hymba's vocab 32001 and whisper's 51866 cannot
    shard 16-way — those dims fall back to replicated.
    """
    import math as _math

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, entry in enumerate(tuple(spec)):
        if entry is None or i >= len(shape):
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        deg = _math.prod(sizes[a] for a in axes)
        out.append(entry if shape[i] % deg == 0 else None)
    return P(*out)


def sanitized_sharding_tree(tree, spec_tree, mesh: Mesh):
    """NamedSharding tree from (abstract) value tree + PartitionSpec tree."""
    return jax.tree.map(
        lambda leaf, s: NamedSharding(mesh, sanitize_spec(s, leaf.shape, mesh)),
        tree,
        spec_tree,
    )


def param_sharding_tree(params, mesh: Mesh, rules: MeshRules):
    specs = param_pspec_tree(params, rules)
    return sanitized_sharding_tree(params, specs, mesh)
