"""Trace-file CLI: summarize, check, or diff Perfetto traces.

Usage::

    python -m repro.telemetry trace.json              # summarize
    python -m repro.telemetry trace.json other.json   # diff two traces
    python -m repro.telemetry --check trace.json      # CI gate

``--check`` exits nonzero if the trace has unclosed spans or the
Cor. 7 window balance ratio gauge exceeds ``--max-balance`` (default
1.05).  A trace without the balance gauge passes the balance check with
a note (not every workload touches the distributed layer).
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from .export import load_trace


def _other(trace: dict) -> dict:
    return trace.get("otherData") or {}


def _fmt_num(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def summarize(trace: dict) -> List[str]:
    other = _other(trace)
    lines = [
        f"clock={other.get('clock', '?')}  "
        f"events={len(trace.get('traceEvents') or [])}  "
        f"unclosed_spans={other.get('unclosed_spans', '?')}"
    ]
    spans = other.get("spans") or {}
    if spans:
        lines.append("spans:")
        for name in sorted(spans):
            rec = spans[name]
            lines.append(
                f"  {name:<40s} count={rec.get('count', 0):<8d} "
                f"total_us={_fmt_num(rec.get('total_us', 0))}"
            )
    counters = other.get("counters") or {}
    if counters:
        lines.append("counters:")
        for name in sorted(counters):
            lines.append(f"  {name:<40s} {_fmt_num(counters[name])}")
    gauges = other.get("gauges") or {}
    if gauges:
        lines.append("gauges:")
        for name in sorted(gauges):
            g = gauges[name]
            lines.append(
                f"  {name:<40s} last={_fmt_num(g.get('last'))} "
                f"min={_fmt_num(g.get('min'))} max={_fmt_num(g.get('max'))}"
            )
    health = other.get("health") or {}
    if health:
        lines.append("health:")
        for op in sorted(health):
            h = health[op]
            lines.append(
                f"  {op:<40s} calls={h.get('calls', 0)} "
                f"fallbacks={h.get('fallbacks', 0)} "
                f"failures={h.get('failures', 0)}"
            )
    return lines


def check(trace: dict, max_balance: float) -> List[str]:
    """Return a list of failure messages (empty → trace is healthy)."""
    other = _other(trace)
    problems = []
    unclosed = other.get("unclosed_spans")
    if unclosed is None:
        problems.append("trace has no otherData.unclosed_spans field")
    elif unclosed != 0:
        problems.append(f"{unclosed} unclosed span(s)")
    balance = (other.get("gauges") or {}).get("distributed.balance_ratio")
    if balance is not None and balance.get("max") is not None:
        if balance["max"] > max_balance:
            problems.append(
                f"window balance ratio {balance['max']:.4f} exceeds "
                f"{max_balance:.4f} (Cor. 7 violated)"
            )
    return problems


def diff(a: dict, b: dict) -> List[str]:
    """Line diff of counters/gauges/span counts between two traces."""
    oa, ob = _other(a), _other(b)
    lines = []

    ca, cb = oa.get("counters") or {}, ob.get("counters") or {}
    for name in sorted(set(ca) | set(cb)):
        va, vb = ca.get(name), cb.get(name)
        if va != vb:
            lines.append(f"counter {name}: {_fmt_num(va)} -> {_fmt_num(vb)}")

    ga, gb = oa.get("gauges") or {}, ob.get("gauges") or {}
    for name in sorted(set(ga) | set(gb)):
        la = (ga.get(name) or {}).get("last")
        lb = (gb.get(name) or {}).get("last")
        if la != lb:
            lines.append(f"gauge {name}: {_fmt_num(la)} -> {_fmt_num(lb)}")

    sa, sb = oa.get("spans") or {}, ob.get("spans") or {}
    for name in sorted(set(sa) | set(sb)):
        na = (sa.get(name) or {}).get("count", 0)
        nb = (sb.get(name) or {}).get("count", 0)
        if na != nb:
            lines.append(f"span {name}: count {na} -> {nb}")

    if not lines:
        lines.append("traces agree on counters, gauges, and span counts")
    return lines


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry",
        description="Summarize, check, or diff Perfetto trace files.",
    )
    ap.add_argument("trace", help="trace JSON file (from telemetry.write_trace)")
    ap.add_argument("other", nargs="?", help="second trace: diff mode")
    ap.add_argument(
        "--check",
        action="store_true",
        help="exit nonzero on unclosed spans or balance-ratio violations",
    )
    ap.add_argument(
        "--max-balance",
        type=float,
        default=1.05,
        help="max allowed distributed.balance_ratio (default 1.05)",
    )
    args = ap.parse_args(argv)

    trace = load_trace(args.trace)

    if args.other is not None:
        for line in diff(trace, load_trace(args.other)):
            print(line)
        return 0

    if args.check:
        problems = check(trace, args.max_balance)
        if problems:
            for p in problems:
                print(f"FAIL: {p}")
            return 1
        balance = (_other(trace).get("gauges") or {}).get("distributed.balance_ratio")
        note = (
            f"balance_ratio max={balance['max']:.4f}"
            if balance is not None and balance.get("max") is not None
            else "balance_ratio gauge absent (no distributed ops in trace)"
        )
        print(f"OK: 0 unclosed spans; {note}")
        return 0

    for line in summarize(trace):
        print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
