"""Trace export: Chrome/Perfetto ``trace_event`` JSON and flat summaries.

The exported trace is loadable in ``chrome://tracing`` / Perfetto's
legacy-JSON importer: closed spans become complete ``"X"`` events with
``ts``/``dur`` in trace microseconds, unclosed spans become lone ``"B"``
events (Perfetto renders them open-ended, and ``--check`` flags them).

Byte-identity contract: everything serialized here is a pure function of
the recorded event stream — timestamps come from the registry's clock
(deterministic under :class:`repro.telemetry.TickClock`), keys are
sorted, separators fixed.  Wall-clock-derived *histograms* are therefore
excluded from the trace file body (they go in :func:`summary`, which
feeds ``BENCH_*.json`` where nondeterminism is expected); a tick-clocked
trace of a deterministic workload serializes to identical bytes on every
replay.
"""

from __future__ import annotations

import json
from typing import Dict

from .spans import Telemetry


def _health_dict(tel: Telemetry) -> Dict[str, dict]:
    return {op: h.as_dict() for op, h in sorted(tel.health.items())}


def chrome_trace(tel: Telemetry) -> dict:
    """Chrome ``trace_event`` JSON object (deterministic content only)."""
    events = []
    for sp in tel.spans:
        ev = {
            "name": sp.name,
            "cat": "repro",
            "pid": 1,
            "tid": 1 + sp.depth,
            "ts": sp.start,
            "args": sp.attrs,
        }
        if sp.end is None:
            ev["ph"] = "B"
        else:
            ev["ph"] = "X"
            ev["dur"] = sp.end - sp.start
        events.append(ev)
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "clock": tel.clock.kind,
            "unclosed_spans": len(tel.unclosed()),
            "spans": tel.span_stats(),
            "counters": {k: c.value for k, c in tel.counters.items()},
            "gauges": {k: g.as_dict() for k, g in tel.gauges.items()},
            "health": _health_dict(tel),
        },
    }


def summary(tel: Telemetry) -> dict:
    """Flat summary dict (the ``telemetry`` block of ``BENCH_*.json``).

    Unlike :func:`chrome_trace` this includes histogram stats, which may
    carry wall-time samples.
    """
    return {
        "clock": tel.clock.kind,
        "unclosed_spans": len(tel.unclosed()),
        "spans": tel.span_stats(),
        "counters": {k: c.value for k, c in tel.counters.items()},
        "gauges": {k: g.as_dict() for k, g in tel.gauges.items()},
        "histograms": {k: h.stats() for k, h in tel.histograms.items()},
        "health": _health_dict(tel),
    }


def trace_json_bytes(tel: Telemetry) -> bytes:
    """Canonical serialized trace — sorted keys, fixed separators, so two
    identical event streams compare equal as raw bytes."""
    return json.dumps(
        chrome_trace(tel), sort_keys=True, separators=(",", ":")
    ).encode("utf-8")


def write_trace(tel: Telemetry, path) -> None:
    with open(path, "wb") as f:
        f.write(trace_json_bytes(tel))


def load_trace(path) -> dict:
    with open(path, "r", encoding="utf-8") as f:
        return json.load(f)
