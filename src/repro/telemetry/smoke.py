"""Trace smoke workload: ``make trace-smoke``.

Runs a small serving workload (reduced tinyllama, 3 requests) under the
deterministic tick clock, plus one eager ``distributed_merge`` of
uniform random inputs so the Cor. 7 balance gauge is populated, then
writes the Perfetto trace.  CI asserts
``python -m repro.telemetry --check <out>`` on the result: zero
unclosed spans and balance ratio <= 1.05.
"""

from __future__ import annotations

import argparse

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="trace.json", help="trace file to write")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import distributed_merge
    from repro.models import init_params
    from repro.serving.engine import Request, ServingEngine
    from repro.telemetry import get_telemetry, write_trace

    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_params(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, batch=2, max_seq=32)
    rng = np.random.default_rng(0)
    for i in range(3):
        eng.submit(
            Request(
                uid=i,
                prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                max_new_tokens=2,
                temperature=0.0,
            )
        )
    rep = eng.run_until_done()
    assert rep.ok(), f"trace smoke workload degraded: {rep}"

    # one eager distributed merge on uniform random inputs: populates the
    # per-device window counters and the balance-ratio gauge
    a = jnp.asarray(np.sort(rng.standard_normal(256)).astype(np.float32))
    b = jnp.asarray(np.sort(rng.standard_normal(256)).astype(np.float32))
    distributed_merge(a, b)

    write_trace(get_telemetry(), args.out)
    print(f"trace-smoke: wrote {args.out} ({rep.ticks} ticks, {rep.completed} completed)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
