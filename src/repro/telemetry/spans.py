"""Structured telemetry core: spans, counters, gauges, histograms.

Dependency-free (stdlib only) so every layer of the stack — kernels,
core, runtime, serving, benchmarks — can record into it without import
cycles or accelerator baggage.  One :class:`Telemetry` registry holds

* **spans** — nestable timed regions recorded through a pluggable clock
  (:class:`WallClock` by default; the :class:`ServingEngine` installs its
  deterministic :class:`TickClock` while serving so traces replay
  bit-identically under the ``REPRO_FAULTS`` injector);
* **counters** — monotonically accumulating integers/floats;
* **gauges** — last/min/max of a sampled value (queue depth, slot
  occupancy, the Cor. 7 window balance ratio);
* **histograms** — fixed-bucket counts *plus* the raw samples, so
  ``percentile`` extraction is exact (numpy-compatible linear
  interpolation) rather than bucket-quantized;
* **health** — the per-op :class:`repro.runtime.resilience.OpHealth`
  records of the guarded dispatch layer live in this registry too (PR 8's
  counters merged into the same place; duck-typed so telemetry itself
  stays dependency-free).

The active registry is process-global (:func:`get_telemetry`); tests and
replay harnesses push a fresh instance with :func:`use`.

Clock semantics
---------------
``Clock.now()`` returns *trace microseconds*.  :class:`WallClock` is
``time.perf_counter()`` scaled to us — this module and
``benchmarks/_timing.py`` are the only places allowed to touch the raw
wall clock (lint rule L007).  :class:`TickClock` maps one engine tick to
:data:`TICK_SCALE` trace-us and disambiguates events inside a tick with a
per-tick sequence number, so the timestamp stream is a pure function of
the event stream — no wall time leaks into a tick-clocked trace.
"""

from __future__ import annotations

import bisect
import time
from contextlib import contextmanager
from typing import Dict, Iterator, List, Optional

TICK_SCALE = 1_000_000  # one engine tick rendered as this many trace-us


class WallClock:
    """Wall time in microseconds (the sanctioned ``perf_counter`` site)."""

    kind = "wall"

    def now(self) -> float:
        return time.perf_counter() * 1e6


WALL = WallClock()


def wall_seconds() -> float:
    """Monotonic wall seconds — the repo-wide replacement for raw
    ``time.perf_counter()`` / ``time.monotonic()`` call sites (L007)."""
    return time.perf_counter()


class TickClock:
    """Deterministic clock counted in engine ticks, not wall time.

    ``now()`` returns ``tick * TICK_SCALE + seq`` where ``seq`` increments
    per read and resets on :meth:`advance` — strictly monotonic within a
    tick, and a pure function of the call sequence, so two replays of the
    same workload produce byte-identical timestamp streams.
    """

    kind = "tick"

    def __init__(self) -> None:
        self.tick = 0
        self._seq = 0

    def advance(self, tick: Optional[int] = None) -> None:
        self.tick = self.tick + 1 if tick is None else int(tick)
        self._seq = 0

    def now(self) -> int:
        ts = self.tick * TICK_SCALE + self._seq
        self._seq += 1
        return ts


class Span:
    """One timed region; ``end is None`` while (or if never) closed."""

    __slots__ = ("name", "start", "end", "depth", "attrs")

    def __init__(self, name: str, start, depth: int, attrs: Dict[str, object]):
        self.name = name
        self.start = start
        self.end = None
        self.depth = depth
        self.attrs = attrs

    def set(self, key: str, value: object) -> None:
        """Attach/overwrite one attribute (e.g. the dispatch label that
        actually served a guarded call)."""
        self.attrs[key] = value

    @property
    def duration(self):
        return None if self.end is None else self.end - self.start


class Counter:
    """Accumulating value; ``add`` only (use a :class:`Gauge` to sample)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def add(self, v=1) -> None:
        self.value += v


class Gauge:
    """Last/min/max of a sampled value."""

    __slots__ = ("last", "min", "max")

    def __init__(self) -> None:
        self.last = None
        self.min = None
        self.max = None

    def set(self, v) -> None:
        self.last = v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    def as_dict(self) -> dict:
        return {"last": self.last, "min": self.min, "max": self.max}


# 1-2-5 bucket ladder from 1 us to 1e7 us (10 s); the last bucket is open.
DEFAULT_BOUNDS = tuple(
    m * 10**e for e in range(8) for m in (1, 2, 5)
)


class Histogram:
    """Fixed-bucket histogram that also keeps the raw samples.

    Buckets make cross-process merging and trace export cheap; the raw
    samples make :meth:`percentile` *exact* — linear interpolation on the
    sorted samples, matching ``numpy.percentile``'s default method.
    """

    __slots__ = ("bounds", "bucket_counts", "samples")

    def __init__(self, bounds=DEFAULT_BOUNDS):
        self.bounds = tuple(bounds)
        self.bucket_counts = [0] * (len(self.bounds) + 1)
        self.samples: List[float] = []

    @property
    def count(self) -> int:
        return len(self.samples)

    def record(self, v) -> None:
        v = float(v)
        self.samples.append(v)
        self.bucket_counts[bisect.bisect_left(self.bounds, v)] += 1

    def percentile(self, q: float) -> float:
        """Exact q-th percentile (numpy 'linear' interpolation)."""
        if not self.samples:
            return 0.0
        s = sorted(self.samples)
        h = (len(s) - 1) * (q / 100.0)
        lo = int(h)
        if lo >= len(s) - 1:
            return s[-1]
        return s[lo] + (h - lo) * (s[lo + 1] - s[lo])

    def stats(self) -> dict:
        """Flat summary row: count/mean/min/max + p50/p95/p99 + buckets."""
        if not self.samples:
            return {"count": 0}
        return {
            "count": len(self.samples),
            "mean": sum(self.samples) / len(self.samples),
            "min": min(self.samples),
            "max": max(self.samples),
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
            "buckets": [
                [self.bounds[i] if i < len(self.bounds) else None, c]
                for i, c in enumerate(self.bucket_counts)
                if c
            ],
        }


class Telemetry:
    """One registry of spans + counters + gauges + histograms + op health."""

    def __init__(self, clock=None):
        self.clock = clock if clock is not None else WallClock()
        self.spans: List[Span] = []
        self._stack: List[Span] = []
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}
        # guarded-dispatch OpHealth records (duck-typed: anything with
        # .as_dict()); populated by repro.runtime.resilience
        self.health: Dict[str, object] = {}

    # -- instruments ------------------------------------------------------

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        return g

    def histogram(self, name: str, bounds=DEFAULT_BOUNDS) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(bounds)
        return h

    # -- spans ------------------------------------------------------------

    def begin(self, name: str, **attrs) -> Span:
        sp = Span(name, self.clock.now(), len(self._stack), dict(attrs))
        self.spans.append(sp)
        self._stack.append(sp)
        return sp

    def end(self, sp: Span) -> None:
        sp.end = self.clock.now()
        # tolerate out-of-order ends (an exception unwinding several spans)
        while self._stack:
            top = self._stack.pop()
            if top is sp:
                break

    @contextmanager
    def span(self, name: str, **attrs) -> Iterator[Span]:
        sp = self.begin(name, **attrs)
        try:
            yield sp
        finally:
            self.end(sp)

    def unclosed(self) -> List[Span]:
        return [sp for sp in self.spans if sp.end is None]

    def span_stats(self) -> Dict[str, dict]:
        """Per-name span aggregate: count + total duration (trace-us)."""
        out: Dict[str, dict] = {}
        for sp in self.spans:
            rec = out.setdefault(sp.name, {"count": 0, "total_us": 0})
            rec["count"] += 1
            if sp.end is not None:
                rec["total_us"] += sp.end - sp.start
        return out

    # -- clock plumbing ---------------------------------------------------

    @contextmanager
    def use_clock(self, clock) -> Iterator[None]:
        prev, self.clock = self.clock, clock
        try:
            yield
        finally:
            self.clock = prev

    # -- lifecycle / cross-process merge ----------------------------------

    def reset(self) -> None:
        self.spans.clear()
        self._stack.clear()
        self.counters.clear()
        self.gauges.clear()
        self.histograms.clear()
        self.health.clear()

    def snapshot(self) -> dict:
        """JSON-able state for shipping across a process boundary
        (``benchmarks/bench_distributed.py``'s forced-mesh subprocess)."""
        return {
            "counters": {k: c.value for k, c in self.counters.items()},
            "gauges": {k: g.as_dict() for k, g in self.gauges.items()},
            "histograms": {k: {"samples": list(h.samples)} for k, h in self.histograms.items()},
            "spans": self.span_stats(),
        }

    def merge_snapshot(self, snap: dict) -> None:
        """Fold another process's :meth:`snapshot` into this registry."""
        for k, v in (snap.get("counters") or {}).items():
            self.counter(k).add(v)
        for k, d in (snap.get("gauges") or {}).items():
            g = self.gauge(k)
            for key in ("min", "last", "max"):  # preserves merged min/max
                if d.get(key) is not None:
                    g.set(d[key])
        for k, d in (snap.get("histograms") or {}).items():
            h = self.histogram(k)
            for s in d.get("samples") or ():
                h.record(s)


_CURRENT: List[Telemetry] = [Telemetry()]


def get_telemetry() -> Telemetry:
    """The active registry (process-global root unless :func:`use`-d)."""
    return _CURRENT[-1]


@contextmanager
def use(tel: Telemetry) -> Iterator[Telemetry]:
    """Install ``tel`` as the active registry for the block (tests, replay
    harnesses, anything needing an isolated event stream)."""
    _CURRENT.append(tel)
    try:
        yield tel
    finally:
        _CURRENT.pop()


def reset_telemetry() -> None:
    """Zero the active registry in place."""
    get_telemetry().reset()
