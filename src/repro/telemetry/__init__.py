"""repro.telemetry — dependency-free structured telemetry.

Spans (pluggable wall/tick clock), counters, gauges, exact-percentile
histograms, Chrome/Perfetto trace export, and the guarded-dispatch
health registry.  See ``docs/observability.md`` for the metric catalog
and ``python -m repro.telemetry --help`` for the trace CLI.
"""

from .spans import (
    TICK_SCALE,
    WALL,
    Counter,
    Gauge,
    Histogram,
    Span,
    Telemetry,
    TickClock,
    WallClock,
    get_telemetry,
    reset_telemetry,
    use,
    wall_seconds,
)
from .export import (
    chrome_trace,
    load_trace,
    summary,
    trace_json_bytes,
    write_trace,
)

__all__ = [
    "TICK_SCALE",
    "WALL",
    "Counter",
    "Gauge",
    "Histogram",
    "Span",
    "Telemetry",
    "TickClock",
    "WallClock",
    "chrome_trace",
    "get_telemetry",
    "load_trace",
    "reset_telemetry",
    "summary",
    "trace_json_bytes",
    "use",
    "wall_seconds",
    "write_trace",
]
