"""Token samplers built on the merge-path top-k (paper integration #2).

``topk_sample`` / ``topp_sample`` use the *batched* merge-path top-k
(``repro.core.topk_batched``): all batch rows ride one fused kv-sort —
every diagonal binary search of every row's merge rounds shares a single
vectorized Algorithm 2 pass — instead of a vmapped per-row sort.  On a
vocab-sharded mesh, ``backend="distributed"`` routes the candidate step
through ``repro.core.distributed_topk_batched``: per-shard batched top-k,
then a butterfly (or gather) merge-path combine that replicates the
global ``(B, k)`` candidates — ``k * log2(P)`` candidates moved per
device instead of the whole vocab (see core/distributed.py).

**Masked vocab** (``vocab_lens``): serving vocabularies are padded to
lane-friendly widths, so only a prefix of every logit row is real.
Instead of faking it by ``-inf``-filling the tail (which collides with
genuinely ``-inf`` logits — banned tokens — once keys are flipped for
the descending sort), the samplers route through
``repro.core.topk_batched_ragged``: the valid length bounds the sort
itself, masked slots return index ``-1``/probability 0, and — when
``vocab_lens[r] >= k`` so both draws see the same candidate count — a
padded row is sampled *bit-identically* to its unpadded truncation.
(With fewer valid tokens than ``k`` the candidate tensor is shaped
differently, so the draw consumes the PRNG differently: the sampled
*distribution* still matches, the exact token for a given key may not.)

Contract for degenerate rows: a row with ``vocab_lens[r] == 0`` has no
valid token to sample, so the samplers return ``-1`` for it — the same
out-of-band marker the ragged top-k uses.  Callers must treat negative
token ids as "no token" (never feed them to a gather, where JAX's
negative indexing would silently wrap to the last vocab entry).  Rows
with ``vocab_lens[r] >= 1`` always return a valid in-prefix id.

**Backend** (``backend="pallas"``): the candidate sort runs on the
hierarchical tile engine (``repro.kernels.ops.topk_batched{,_ragged}``)
instead of the fused pure-JAX path — same stable contract and the same
ragged semantics, with ``(tile, leaf)`` either passed explicitly or
resolved from the autotune table (``repro.kernels.tune``).  Production
vocab widths (32K-256K) sit squarely in the regime where the kernel's
flat sort rounds win.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import topk_batched, topk_batched_ragged
from repro.core.merge_path import min_sentinel


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _topk_candidates(
    logits: jax.Array,
    k: int,
    vocab_lens,
    backend: str = "core",
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    mesh=None,
    axis: str = "x",
) -> Tuple[jax.Array, jax.Array]:
    """Per-row top-k candidates, optionally over a ragged valid-vocab prefix."""
    if backend == "distributed":
        from repro.core import distributed_topk_batched  # deferred: mesh layer optional

        if vocab_lens is not None:
            raise ValueError(
                "vocab_lens is not supported with backend='distributed' — pad "
                "the sharded vocab with -inf ban logits instead"
            )
        return distributed_topk_batched(logits, k, mesh=mesh, axis=axis)
    if backend == "pallas":
        from repro.kernels import ops as kops  # deferred: kernels layer optional here

        if vocab_lens is None:
            return kops.topk_batched(logits, k, tile=tile, leaf=leaf)
        return kops.topk_batched_ragged(logits, k, vocab_lens, tile=tile, leaf=leaf)
    if vocab_lens is None:
        return topk_batched(logits, k)
    return topk_batched_ragged(logits, k, vocab_lens)


def topk_sample(
    logits: jax.Array,  # (B, V)
    key: jax.Array,
    k: int = 40,
    temperature: float = 1.0,
    vocab_lens=None,  # optional (B,) or scalar: valid vocab prefix per row
    backend: str = "core",  # "core" | "pallas" | "distributed" (vocab-sharded)
    tile: Optional[int] = None,  # kernel tile override (None = autotuned)
    leaf: Optional[int] = None,  # kernel leaf override (None = autotuned)
    mesh=None,  # backend="distributed": mesh whose `axis` shards the vocab
    axis: str = "x",
) -> jax.Array:
    vals, idx = _topk_candidates(logits, k, vocab_lens, backend, tile, leaf, mesh, axis)
    probs = jax.nn.softmax(vals.astype(jnp.float32) / jnp.maximum(temperature, 1e-6), axis=-1)
    loglik = jnp.log(jnp.maximum(probs, 1e-30))
    # masked-vocab slots are -inf, not floor-probability: they can never be
    # drawn while any valid candidate exists (a lens==0 row returns -1)
    loglik = jnp.where(idx >= 0, loglik, min_sentinel(loglik.dtype))
    choice = jax.random.categorical(key, loglik)
    return jnp.take_along_axis(idx, choice[:, None], axis=-1)[:, 0].astype(jnp.int32)


def topp_sample(
    logits: jax.Array,
    key: jax.Array,
    p: float = 0.9,
    k_max: int = 128,
    temperature: float = 1.0,
    vocab_lens=None,
    backend: str = "core",  # "core" | "pallas" | "distributed" (vocab-sharded)
    tile: Optional[int] = None,
    leaf: Optional[int] = None,
    mesh=None,  # backend="distributed": mesh whose `axis` shards the vocab
    axis: str = "x",
) -> jax.Array:
    """Nucleus sampling over the merge-path-sorted top-k_max candidates."""
    vals, idx = _topk_candidates(logits, k_max, vocab_lens, backend, tile, leaf, mesh, axis)
    probs = jax.nn.softmax(vals.astype(jnp.float32) / jnp.maximum(temperature, 1e-6), axis=-1)
    probs = jnp.where(idx >= 0, probs, 0.0)
    cum = jnp.cumsum(probs, axis=-1)
    keep = cum - probs < p  # always keeps the first candidate
    probs = jnp.where(keep, probs, 0.0)
    loglik = jnp.log(jnp.maximum(probs, 1e-30))
    loglik = jnp.where(idx >= 0, loglik, min_sentinel(loglik.dtype))  # see topk_sample
    choice = jax.random.categorical(key, loglik)
    return jnp.take_along_axis(idx, choice[:, None], axis=-1)[:, 0].astype(jnp.int32)
