"""Token samplers built on the merge-path top-k (paper integration #2).

``topk_sample`` / ``topp_sample`` use the *batched* merge-path top-k
(``repro.core.topk_batched``): all batch rows ride one fused kv-sort —
every diagonal binary search of every row's merge rounds shares a single
vectorized Algorithm 2 pass — instead of a vmapped per-row sort.  On a
vocab-sharded mesh the serving engine swaps in
``repro.core.distributed_topk`` whose combine step is a tree of
merge-path merges (see core/distributed.py).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import topk_batched


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def topk_sample(
    logits: jax.Array,  # (B, V)
    key: jax.Array,
    k: int = 40,
    temperature: float = 1.0,
) -> jax.Array:
    vals, idx = topk_batched(logits, k)
    probs = jax.nn.softmax(vals.astype(jnp.float32) / jnp.maximum(temperature, 1e-6), axis=-1)
    choice = jax.random.categorical(key, jnp.log(jnp.maximum(probs, 1e-30)))
    return jnp.take_along_axis(idx, choice[:, None], axis=-1)[:, 0].astype(jnp.int32)


def topp_sample(
    logits: jax.Array,
    key: jax.Array,
    p: float = 0.9,
    k_max: int = 128,
    temperature: float = 1.0,
) -> jax.Array:
    """Nucleus sampling over the merge-path-sorted top-k_max candidates."""
    vals, idx = topk_batched(logits, k_max)
    probs = jax.nn.softmax(vals.astype(jnp.float32) / jnp.maximum(temperature, 1e-6), axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep = cum - probs < p  # always keeps the first candidate
    probs = jnp.where(keep, probs, 0.0)
    choice = jax.random.categorical(key, jnp.log(jnp.maximum(probs, 1e-30)))
    return jnp.take_along_axis(idx, choice[:, None], axis=-1)[:, 0].astype(jnp.int32)
