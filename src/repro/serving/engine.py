"""Batched serving engine: slot-based continuous batching.

A fixed pool of ``batch`` slots; finished/empty slots are refilled from
the request queue (prefill), all occupied slots decode in lockstep (one
jitted decode step per tick).  Per-slot absolute positions make the
lockstep correct for ragged prompt lengths.  Sampling uses the
merge-path top-k sampler.

Graceful degradation
--------------------
The engine never drops a request silently: every submitted request ends
in ``engine.done`` with an explicit terminal ``status`` —

* ``completed`` — generated ``max_new_tokens`` (or hit the sequence cap);
* ``timed_out`` — exceeded its per-request ``deadline_ticks`` budget (or
  the engine ran out of ``run_until_done`` ticks) with its partial
  ``generated`` tokens preserved;
* ``shed``      — rejected at ``submit`` because the queue was full
  (``max_pending``), or never scheduled before the tick budget drained;
* ``failed``    — the decode step failed ``max_retries`` consecutive
  times while the request was in flight (partial tokens preserved).

A failed tick (an exception out of the jitted decode — e.g. an injected
``launch:serving.decode`` fault from :mod:`repro.runtime.faults`) does
not kill the engine: it backs off for ``min(backoff_base * 2**(streak-1),
backoff_cap)`` ticks and retries; only after ``max_retries`` consecutive
failures are the in-flight requests terminated (``failed``), after which
the engine recovers and keeps serving the queue.  All timing is counted
in deterministic engine *ticks* — never wall clock — so every degradation
path replays exactly under the fault injector.

``run_until_done`` returns a :class:`ServingReport` summarising the
outcome; ``report.ok()`` is the zero-degradation check CI asserts on a
clean tree.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import forward_decode, forward_prefill, init_caches
from repro.runtime import faults as _faults
from repro.runtime.resilience import FallbackWarning
from repro.telemetry import WALL, TickClock, get_telemetry
from repro.train.steps import _cast
from . import sampler as sampler_mod


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (L,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 => greedy
    topk: int = 40
    deadline_ticks: Optional[int] = None  # tick budget from submission; None = no deadline
    # outputs
    generated: Optional[List[int]] = None
    status: str = "pending"  # pending | completed | timed_out | shed | failed
    reason: str = ""


@dataclasses.dataclass
class ServingReport:
    """Outcome summary returned by :meth:`ServingEngine.run_until_done`."""

    ticks: int = 0
    completed: int = 0
    timed_out: int = 0
    shed: int = 0
    failed: int = 0
    retries: int = 0
    statuses: Dict[int, str] = dataclasses.field(default_factory=dict)
    reasons: Dict[int, str] = dataclasses.field(default_factory=dict)
    # serving metric block (tick histograms + occupancy/queue gauges),
    # folded in by run_until_done from the active telemetry registry
    telemetry: Dict[str, object] = dataclasses.field(default_factory=dict)

    def ok(self) -> bool:
        """True when every request completed and no tick was retried."""
        return self.timed_out == 0 and self.shed == 0 and self.failed == 0


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        batch: int,
        max_seq: int,
        seed: int = 0,
        max_pending: Optional[int] = None,
        max_retries: int = 3,
        backoff_base: int = 1,
        backoff_cap: int = 8,
    ):
        self.cfg = cfg
        self.compute_dtype = jnp.dtype(cfg.dtype)
        self.params = _cast(params, self.compute_dtype)
        self.batch = batch
        self.max_seq = max_seq
        self.key = jax.random.key(seed)
        self.caches = init_caches(cfg, batch, max_seq)
        self.pos = np.zeros(batch, np.int32)
        self.active: List[Optional[Request]] = [None] * batch
        self.pending: List[Request] = []
        self.done: Dict[int, Request] = {}
        self.max_pending = max_pending
        self.max_retries = max_retries
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.ticks = 0
        self.retries = 0
        self._cooldown = 0
        self._fail_streak = 0
        # Deterministic span clock: while the engine steps, telemetry
        # timestamps count engine ticks (never wall time), so a
        # fault-injected run replays to a byte-identical trace.
        self.tick_clock = TickClock()
        self._decode = jax.jit(
            lambda params, caches, tok, pos: forward_decode(cfg, params, caches, tok, pos)
        )

    # -- request lifecycle ------------------------------------------------

    def submit(self, req: Request) -> None:
        """Queue a request — or shed it, loudly, when the queue is full."""
        req.generated = []
        req._submit_tick = self.ticks
        if self.max_pending is not None and len(self.pending) >= self.max_pending:
            self._finish(req, "shed", f"queue full (max_pending={self.max_pending})")
            return
        req.status = "pending"
        self.pending.append(req)

    def _finish(self, req: Request, status: str, reason: str = "") -> None:
        req.status = status
        req.reason = reason
        if req.generated is None:
            req.generated = []
        self.done[req.uid] = req
        if status != "completed":
            warnings.warn(
                f"serving: request {req.uid} {status}"
                + (f" ({reason})" if reason else ""),
                FallbackWarning,
                stacklevel=4,
            )

    def _expire_deadlines(self) -> None:
        """Terminate (loudly) every request past its tick budget."""
        for slot in range(self.batch):
            req = self.active[slot]
            if req is not None and self._past_deadline(req):
                self._finish(req, "timed_out", f"deadline_ticks={req.deadline_ticks} exceeded")
                self.active[slot] = None
        kept = []
        for req in self.pending:
            if self._past_deadline(req):
                self._finish(req, "timed_out", f"deadline_ticks={req.deadline_ticks} in queue")
            else:
                kept.append(req)
        self.pending = kept

    def _past_deadline(self, req: Request) -> bool:
        if req.deadline_ticks is None:
            return False
        return self.ticks - getattr(req, "_submit_tick", 0) >= req.deadline_ticks

    # -- decode -----------------------------------------------------------

    def _fill_slot(self, slot: int, req: Request) -> None:
        """Prefill one request into a slot by stepping its prompt tokens.

        Slot-wise decode-based prefill keeps the engine simple (batched
        prompt prefill is the launch/dryrun `prefill` path); fine for the
        CPU example scale this engine runs at.
        """
        prompt = req.prompt.astype(np.int32)
        for t, tok in enumerate(prompt):
            token = jnp.zeros((self.batch, 1), jnp.int32).at[slot, 0].set(int(tok))
            pos = jnp.asarray(np.where(np.arange(self.batch) == slot, t, self.pos), jnp.int32)
            logits, self.caches = self._decode(self.params, self.caches, token, pos)
        self.pos[slot] = len(prompt)
        self.active[slot] = req
        self._last_logits = logits  # (B, V)
        req._next_from_prefill = np.asarray(logits[slot])

    def _sample(self, req: Request, logits_row: np.ndarray) -> int:
        lrow = jnp.asarray(logits_row)[None]
        if req.temperature <= 0:
            return int(sampler_mod.greedy(lrow)[0])
        self.key, sub = jax.random.split(self.key)
        return int(sampler_mod.topk_sample(lrow, sub, k=req.topk, temperature=req.temperature)[0])

    def _tick_body(self) -> None:
        """Refill free slots, then one lockstep decode."""
        tel = get_telemetry()
        for slot in range(self.batch):
            if self.active[slot] is None and self.pending:
                req = self.pending.pop(0)
                self._fill_slot(slot, req)
                first = self._sample(req, req._next_from_prefill)
                req.generated.append(first)
                tel.histogram("serving.ticks_to_first_token").record(
                    self.ticks - getattr(req, "_submit_tick", 0)
                )
                req._last_tok_tick = self.ticks
        occupied = [s for s in range(self.batch) if self.active[s] is not None]
        # sampled *after* refill: a request admitted and finished within one
        # tick still counts toward the occupancy it actually used
        tel.gauge("serving.slot_occupancy").set(len(occupied))
        tel.histogram("serving.slot_occupancy").record(len(occupied))
        if not occupied:
            return
        token = np.zeros((self.batch, 1), np.int32)
        for s in occupied:
            token[s, 0] = self.active[s].generated[-1]
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(token), jnp.asarray(self.pos)
        )
        logits_np = np.asarray(logits)
        for s in occupied:
            req = self.active[s]
            self.pos[s] += 1
            nxt = self._sample(req, logits_np[s])
            req.generated.append(nxt)
            tel.histogram("serving.ticks_per_token").record(
                self.ticks - getattr(req, "_last_tok_tick", self.ticks)
            )
            req._last_tok_tick = self.ticks
            if len(req.generated) >= req.max_new_tokens or self.pos[s] >= self.max_seq - 1:
                self._finish(req, "completed")
                self.active[s] = None

    def _on_step_failure(self, err: BaseException) -> None:
        self._fail_streak += 1
        self.retries += 1
        if self._fail_streak > self.max_retries:
            # Retry budget exhausted: terminate the in-flight requests with
            # their partial tokens, then recover — the queue keeps draining.
            for slot in range(self.batch):
                req = self.active[slot]
                if req is not None:
                    self._finish(
                        req,
                        "failed",
                        f"decode failed {self._fail_streak}x: {type(err).__name__}: {err}",
                    )
                    self.active[slot] = None
            self._fail_streak = 0
            self._cooldown = 0
            return
        self._cooldown = min(self.backoff_base * (2 ** (self._fail_streak - 1)), self.backoff_cap)
        warnings.warn(
            f"serving: decode tick failed ({type(err).__name__}: {err}); "
            f"retry {self._fail_streak}/{self.max_retries} after {self._cooldown} tick(s)",
            FallbackWarning,
            stacklevel=3,
        )

    def step(self) -> None:
        """One engine tick: expire deadlines, then refill + lockstep decode.

        A tick spent cooling down after a failed decode still advances the
        clock (deadlines keep expiring), so a wedged backend cannot stall
        requests forever.
        """
        self.ticks += 1
        tel = get_telemetry()
        self.tick_clock.advance(self.ticks)
        occupied = sum(a is not None for a in self.active)
        queue_depth = len(self.pending)
        tel.gauge("serving.queue_depth").set(queue_depth)
        tel.histogram("serving.queue_depth").record(queue_depth)
        wall0 = WALL.now()
        with tel.use_clock(self.tick_clock), tel.span(
            "serving.tick",
            tick=self.ticks,
            occupied=occupied,
            queue_depth=queue_depth,
        ) as sp:
            self._expire_deadlines()
            if self._cooldown > 0:
                self._cooldown -= 1
                sp.set("cooldown", True)
                tel.counter("serving.cooldown_ticks").add(1)
                return
            idx = _faults.next_index("serving.decode")
            try:
                if _faults.should_fire("launch", "serving.decode", idx, label="decode"):
                    raise _faults.InjectedFault(f"injected launch failure: serving.decode[{idx}]")
                self._tick_body()
            except Exception as err:
                sp.set("failed", type(err).__name__)
                self._on_step_failure(err)
                return
            finally:
                # wall duration goes to a histogram only — never into the
                # (tick-clocked, byte-identical) trace event stream
                tel.histogram("serving.tick_wall_us").record(WALL.now() - wall0)
            self._fail_streak = 0

    # -- draining ---------------------------------------------------------

    def _report(self) -> ServingReport:
        rep = ServingReport(ticks=self.ticks, retries=self.retries)
        for uid, req in self.done.items():
            rep.statuses[uid] = req.status
            if req.reason:
                rep.reasons[uid] = req.reason
            if req.status == "completed":
                rep.completed += 1
            elif req.status == "timed_out":
                rep.timed_out += 1
            elif req.status == "shed":
                rep.shed += 1
            elif req.status == "failed":
                rep.failed += 1
        return rep

    def run_until_done(self, max_ticks: int = 10_000) -> ServingReport:
        """Drain the engine; always return a :class:`ServingReport`.

        On hitting ``max_ticks`` no request is abandoned silently: in-flight
        requests are marked ``timed_out`` (partial ``generated`` preserved)
        and still-queued requests are marked ``shed``, all landing in
        ``self.done`` with explicit reasons.
        """
        tel = get_telemetry()
        with tel.use_clock(self.tick_clock), tel.span("serving.run", batch=self.batch):
            for _ in range(max_ticks):
                if not self.pending and all(a is None for a in self.active):
                    break
                self.step()
            else:
                for slot in range(self.batch):
                    req = self.active[slot]
                    if req is not None:
                        self._finish(req, "timed_out", f"engine out of ticks (max_ticks={max_ticks})")
                        self.active[slot] = None
                for req in self.pending:
                    self._finish(req, "shed", f"never scheduled within max_ticks={max_ticks}")
                self.pending = []
        rep = self._report()
        rep.telemetry = {
            "tick_wall_us": tel.histogram("serving.tick_wall_us").stats(),
            "ticks_to_first_token": tel.histogram("serving.ticks_to_first_token").stats(),
            "ticks_per_token": tel.histogram("serving.ticks_per_token").stats(),
            "slot_occupancy": tel.histogram("serving.slot_occupancy").stats(),
            "queue_depth": tel.histogram("serving.queue_depth").stats(),
        }
        return rep
