"""Batched serving engine: slot-based continuous batching.

A fixed pool of ``batch`` slots; finished/empty slots are refilled from
the request queue (prefill), all occupied slots decode in lockstep (one
jitted decode step per tick).  Per-slot absolute positions make the
lockstep correct for ragged prompt lengths.  Sampling uses the
merge-path top-k sampler.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import forward_decode, forward_prefill, init_caches
from repro.train.steps import _cast
from . import sampler as sampler_mod


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (L,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 => greedy
    topk: int = 40
    # outputs
    generated: Optional[List[int]] = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, batch: int, max_seq: int, seed: int = 0):
        self.cfg = cfg
        self.compute_dtype = jnp.dtype(cfg.dtype)
        self.params = _cast(params, self.compute_dtype)
        self.batch = batch
        self.max_seq = max_seq
        self.key = jax.random.key(seed)
        self.caches = init_caches(cfg, batch, max_seq)
        self.pos = np.zeros(batch, np.int32)
        self.active: List[Optional[Request]] = [None] * batch
        self.pending: List[Request] = []
        self.done: Dict[int, Request] = {}
        self._decode = jax.jit(
            lambda params, caches, tok, pos: forward_decode(cfg, params, caches, tok, pos)
        )

    def submit(self, req: Request) -> None:
        req.generated = []
        self.pending.append(req)

    def _fill_slot(self, slot: int, req: Request) -> None:
        """Prefill one request into a slot by stepping its prompt tokens.

        Slot-wise decode-based prefill keeps the engine simple (batched
        prompt prefill is the launch/dryrun `prefill` path); fine for the
        CPU example scale this engine runs at.
        """
        prompt = req.prompt.astype(np.int32)
        for t, tok in enumerate(prompt):
            token = jnp.zeros((self.batch, 1), jnp.int32).at[slot, 0].set(int(tok))
            pos = jnp.asarray(np.where(np.arange(self.batch) == slot, t, self.pos), jnp.int32)
            logits, self.caches = self._decode(self.params, self.caches, token, pos)
        self.pos[slot] = len(prompt)
        self.active[slot] = req
        self._last_logits = logits  # (B, V)
        req._next_from_prefill = np.asarray(logits[slot])

    def _sample(self, req: Request, logits_row: np.ndarray) -> int:
        lrow = jnp.asarray(logits_row)[None]
        if req.temperature <= 0:
            return int(sampler_mod.greedy(lrow)[0])
        self.key, sub = jax.random.split(self.key)
        return int(sampler_mod.topk_sample(lrow, sub, k=req.topk, temperature=req.temperature)[0])

    def step(self) -> None:
        """One engine tick: refill free slots, then one lockstep decode."""
        for slot in range(self.batch):
            if self.active[slot] is None and self.pending:
                req = self.pending.pop(0)
                self._fill_slot(slot, req)
                first = self._sample(req, req._next_from_prefill)
                req.generated.append(first)
        occupied = [s for s in range(self.batch) if self.active[s] is not None]
        if not occupied:
            return
        token = np.zeros((self.batch, 1), np.int32)
        for s in occupied:
            token[s, 0] = self.active[s].generated[-1]
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(token), jnp.asarray(self.pos)
        )
        logits_np = np.asarray(logits)
        for s in occupied:
            req = self.active[s]
            self.pos[s] += 1
            nxt = self._sample(req, logits_np[s])
            req.generated.append(nxt)
            if len(req.generated) >= req.max_new_tokens or self.pos[s] >= self.max_seq - 1:
                self.done[req.uid] = req
                self.active[s] = None

    def run_until_done(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self.pending and all(a is None for a in self.active):
                return
            self.step()
        raise TimeoutError("serving engine did not drain")
