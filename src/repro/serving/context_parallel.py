"""Context-parallel decode attention (beyond-paper optimization).

For long-context decode (long_500k) the KV cache's sequence dim is
sharded over the ``model`` axis.  Left to GSPMD, the attention einsum
triggers an all-gather of the cache (O(S) wire bytes per step).  This
module computes attention *locally per shard* and combines with an
online-softmax (max / sum / weighted-value) reduction — O(heads x
head_dim) wire bytes per step instead of O(S).

This is the same merge-of-partial-results shape as the paper's
Theorem 5 (independent segment merges + cheap combine), applied to
softmax attention over a sequence-partitioned cache.

Used via ``shard_map`` inside the jitted decode step when
``rules.context`` is set and the engine enables it (hillclimb variant
``context_parallel_combine``).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def local_partial_attention(
    q: jax.Array,  # (B, K, G, hd) — replicated across the context axis
    k_shard: jax.Array,  # (B, S_local, K, hd)
    v_shard: jax.Array,  # (B, S_local, K, hd)
    valid: jax.Array,  # (B, S_local) bool
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-shard (m, l, o): running max, normalizer, weighted values."""
    hd = q.shape[-1]
    s = jnp.einsum("bkgh,bskh->bkgs", q, k_shard).astype(jnp.float32) / math.sqrt(hd)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1)  # (B,K,G)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p.astype(v_shard.dtype), v_shard).astype(jnp.float32)
    return m, l, o


def combine_partials(m, l, o, axis_name: str):
    """Online-softmax combine across the context axis (psum-style).

    wire bytes: 2*(B*K*G) + B*K*G*hd floats — independent of S.
    """
    m_glob = jax.lax.pmax(m, axis_name)
    scale = jnp.exp(m - m_glob)
    l_scaled = l * scale
    o_scaled = o * scale[..., None]
    l_glob = jax.lax.psum(l_scaled, axis_name)
    o_glob = jax.lax.psum(o_scaled, axis_name)
    return o_glob / jnp.maximum(l_glob[..., None], 1e-30)


def context_parallel_decode_attention(
    q: jax.Array,  # (B, K, G, hd)
    k_shard: jax.Array,
    v_shard: jax.Array,
    valid: jax.Array,
    axis_name: str,
) -> jax.Array:
    """Full context-parallel decode attention body (inside shard_map)."""
    m, l, o = local_partial_attention(q, k_shard, v_shard, valid)
    return combine_partials(m, l, o, axis_name)
