"""Mixture-of-Experts with **merge-path sorted dispatch**.

This is the paper's technique as a first-class framework feature: token →
expert routing is a *stable key-value merge sort* (``repro.core``) of the
flat (expert_id, slot) assignment list.  Stability gives a deterministic,
position-ordered drop policy under finite expert capacity — the property
GPU MoE stacks get from radix/merge-path sorts (cf. the paper's §5 GPU
lineage) and that one-hot-einsum dispatch pays O(tokens·E·C) memory for.

Pipeline (per batch row, vmapped so the batch axis stays data-sharded):

1. router logits -> top-k experts per token (k small: lax.top_k)
2. flat assignment keys ``expert_id`` with values ``slot = token*k + j``
3. stable merge-path kv-sort groups assignments by expert, preserving
   token order within each expert
4. position-in-expert = sorted_rank - expert_offset (offsets by binary
   search over the sorted keys — a cross-diagonal search, Alg. 2 again)
5. scatter token embeddings into (E, capacity, d); batched expert matmul;
   combine with router weights.

``moe_dispatch="cumsum"`` selects the conventional one-hot-cumsum
position computation as the ablation baseline (benchmarks table 2).

**Gradients.** Every dispatch route is differentiable, including
``"merge_path_pallas"``: the sort acts on integer (expert_id, slot) pairs
— a pure permutation with no float tangents — and the float scatter /
gather / combine steps are plain ``.at[]`` indexing with exact transpose
rules (the kernel-backed float sorts in ``repro.kernels.ops`` carry their
own permutation-transpose ``custom_vjp``).  ``train/steps.py`` therefore
trains on the kernel path directly; there is no oracle-route fallback
under ``forward_train``.
"""

from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import (
    max_sentinel,
    merge_sort_kv_batched,
    merge_sort_kv_batched_ragged,
    searchsorted_batched,
)
from repro.core.batched import _mask_rows
from repro.parallel.sharding import constrain
from .layers import dense_init, mlp_apply, mlp_init, _act


def moe_init(key, cfg: ModelConfig, dtype) -> Dict:
    d, e, fe = cfg.d_model, cfg.num_experts, cfg.d_ff
    keys = jax.random.split(key, 5)
    p = {
        "router": dense_init(keys[0], (d, e), d, jnp.float32),
        "wg": dense_init(keys[1], (e, d, fe), d, dtype),
        "wi": dense_init(keys[2], (e, d, fe), d, dtype),
        "wo": dense_init(keys[3], (e, fe, d), fe, dtype),
    }
    if cfg.shared_expert_ff:
        p["shared"] = mlp_init(keys[4], d, cfg.shared_expert_ff, "silu", dtype)
    return p


def capacity(cfg: ModelConfig, tokens_per_row: int) -> int:
    c = int(math.ceil(tokens_per_row * cfg.experts_per_token * cfg.capacity_factor / cfg.num_experts))
    return max(8, -(-c // 8) * 8)  # pad to lane-friendly multiple


def _positions_merge_path_batched(
    flat_expert: jax.Array,
    e: int,
    slot_lens: jax.Array | None = None,
    backend: str = "core",
) -> jax.Array:
    """Merge-path dispatch for the whole batch: position-in-expert per slot.

    flat_expert: (B, N) int32 expert ids (N = tokens*k per row).  Returns
    (B, N) position_in_expert aligned with the input slots.

    One batched stable kv-sort (``repro.core.batched``) groups every row's
    assignments by expert simultaneously — all rows, runs and diagonal
    searches share a single fused Algorithm 2 pass instead of a vmapped
    per-row sort.  Expert start offsets fall out of a batched binary
    search over the sorted ids (the same cross-diagonal search).

    ``slot_lens`` makes the dispatch **ragged**: only the first
    ``slot_lens[r]`` slots of row ``r`` (= ``valid_tokens * k``, padding
    tokens sit at the sequence tail) are routed.  The ragged kv-sort
    pushes masked slots past every real assignment, so padding tokens
    can never consume expert capacity and every valid token keeps the
    position it would have in an unpadded batch.  Masked slots report
    an over-capacity position, so the usual ``pos < capacity``
    test drops them with no extra mask.

    ``backend="pallas"`` (``moe_dispatch="merge_path_pallas"``) routes the
    routing sort through the hierarchical tile engine
    (``repro.kernels.ops.sort_kv_batched``, autotuned ``(tile, leaf)``)
    — same stable-sort contract, wide rows ride the flat round kernel.
    The ragged form masks the expert keys to the sentinel first, exactly
    the reduction ``merge_sort_kv_batched_ragged`` applies internally.
    """
    b, n = flat_expert.shape
    slots = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (b, n))
    if backend == "pallas":
        from repro.kernels import ops as kops  # deferred: kernels layer is optional here

        keys = flat_expert
        if slot_lens is not None:
            keys = _mask_rows(keys, slot_lens, max_sentinel(keys.dtype))
        sorted_e, sorted_slot = kops.sort_kv_batched(keys, slots)  # stable
    elif slot_lens is None:
        sorted_e, sorted_slot = merge_sort_kv_batched(flat_expert, slots)  # stable
    else:
        sorted_e, sorted_slot = merge_sort_kv_batched_ragged(
            flat_expert, slots, slot_lens
        )
    experts = jnp.broadcast_to(jnp.arange(e, dtype=flat_expert.dtype)[None, :], (b, e))
    offsets = searchsorted_batched(sorted_e, experts, side="left")  # (B, E)
    pos_sorted = jnp.arange(n, dtype=jnp.int32)[None, :] - jnp.take_along_axis(
        offsets, jnp.clip(sorted_e.astype(jnp.int32), 0, e - 1), axis=1
    )
    if slot_lens is not None:
        # masked slots (rank >= row length) always report an over-capacity
        # position; real slots are unaffected
        pos_sorted = jnp.where(
            jnp.arange(n, dtype=jnp.int32)[None, :] < slot_lens[:, None],
            pos_sorted,
            jnp.int32(2**30),
        )
    # scatter positions back to original slot order
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    return jnp.zeros((b, n), jnp.int32).at[rows, sorted_slot].set(pos_sorted)


def _positions_merge_path(flat_expert: jax.Array, e: int) -> jax.Array:
    """Single-row form of :func:`_positions_merge_path_batched` (tests/ablation)."""
    return _positions_merge_path_batched(flat_expert[None, :], e)[0]


def _positions_cumsum(flat_expert: jax.Array, e: int) -> jax.Array:
    """Ablation baseline: one-hot cumsum position-in-expert (O(N*E))."""
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)  # (N,E)
    pos = jnp.cumsum(onehot, axis=0) - 1
    return jnp.take_along_axis(pos, flat_expert[:, None], axis=1)[:, 0]


def moe_apply(
    params: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    token_counts: jax.Array | None = None,
) -> jax.Array:
    """x (B,S,d) -> (B,S,d). Batch axis stays sharded; experts tensor-sharded.

    ``token_counts`` (optional, ``(B,)`` int32) marks each row's valid
    token count — padding tokens occupy the sequence tail.  With it, the
    merge-path dispatch runs **ragged**: padded tokens are masked out of
    the routing sort, never consume expert capacity, and contribute zero
    output, so every valid token gets exactly the capacity position it
    would get in an unpadded batch.
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = capacity(cfg, s)
    router_logits = (x.astype(jnp.float32) @ params["router"])  # (B,S,E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (B,S,k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # Position-in-expert for ALL batch rows at once: the merge-path path is
    # one batched stable kv-sort (a single fused Alg. 2 pass across the
    # whole batch) rather than a vmapped per-row sort.
    flat_e = top_e.reshape(b, s * k).astype(jnp.int32)  # (B, S*k)
    slot_lens = None
    if token_counts is not None:
        # slots are token-major, so valid slots form the prefix tokens*k
        slot_lens = jnp.clip(jnp.asarray(token_counts, jnp.int32), 0, s) * k
    if cfg.moe_dispatch in ("merge_path", "merge_path_pallas"):
        backend = "pallas" if cfg.moe_dispatch == "merge_path_pallas" else "core"
        pos = _positions_merge_path_batched(flat_e, e, slot_lens, backend)  # (B, S*k)
    else:
        pos = jax.vmap(lambda fe: _positions_cumsum(fe, e))(flat_e)
        if slot_lens is not None:
            slot_ids = jnp.arange(s * k, dtype=jnp.int32)[None, :]
            pos = jnp.where(slot_ids < slot_lens[:, None], pos, jnp.int32(2**30))
    kept = pos < cap
    tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)[None, :], (b, s * k)
    )

    def dispatch_row(xrow, flat_e_r, pos_r, kept_r, tok_r):
        # scatter embeddings into (E, cap, d); dropped slots go nowhere
        buf = jnp.zeros((e, cap, d), xrow.dtype)
        return buf.at[flat_e_r, jnp.where(kept_r, pos_r, cap)].set(
            xrow[tok_r], mode="drop"
        )

    buf = jax.vmap(dispatch_row)(x, flat_e, pos, kept, tok)
    buf = constrain(buf, "act_batch", "act_experts", None, None)
    # batched expert MLP: (B,E,C,d) x (E,d,f) -> (B,E,C,f)
    up = jnp.einsum("becd,edf->becf", buf, params["wi"])
    gate = jnp.einsum("becd,edf->becf", buf, params["wg"])
    h = _act("silu", gate, up)
    h = constrain(h, "act_batch", "act_experts", None, None)
    out_buf = jnp.einsum("becf,efd->becd", h, params["wo"])  # (B,E,C,d)

    def combine_row(obuf, flat_e_r, pos_r, kept_r, tok_r, prow):
        # gather expert outputs back to token slots, weight, and sum over k
        vals = obuf[flat_e_r, jnp.minimum(pos_r, cap - 1)]  # (S*k, d)
        w = prow.reshape(-1)[:, None].astype(vals.dtype) * kept_r[:, None]
        y = jnp.zeros((s, d), vals.dtype).at[tok_r].add(vals * w)
        return y

    y = jax.vmap(combine_row)(out_buf, flat_e, pos, kept, tok, top_p)
    if "shared" in params:
        y = y + mlp_apply(params["shared"], x, "silu")
    return y.astype(x.dtype)


def aux_load_balance_loss(router_logits: jax.Array, top_e: jax.Array, e: int) -> jax.Array:
    """Switch-style load-balance auxiliary loss (available to train cfg)."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(probs.reshape(-1, e), axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(top_e.reshape(-1), e, dtype=jnp.float32), axis=0
    )
    return e * jnp.sum(me * ce)
