"""Mixture-of-Experts with **merge-path sorted dispatch**.

This is the paper's technique as a first-class framework feature: token →
expert routing is a *stable key-value merge sort* (``repro.core``) of the
flat (expert_id, slot) assignment list.  Stability gives a deterministic,
position-ordered drop policy under finite expert capacity — the property
GPU MoE stacks get from radix/merge-path sorts (cf. the paper's §5 GPU
lineage) and that one-hot-einsum dispatch pays O(tokens·E·C) memory for.

Pipeline (per batch row, vmapped so the batch axis stays data-sharded):

1. router logits -> top-k experts per token (k small: lax.top_k)
2. flat assignment keys ``expert_id`` with values ``slot = token*k + j``
3. stable merge-path kv-sort groups assignments by expert, preserving
   token order within each expert
4. position-in-expert = sorted_rank - expert_offset (offsets by binary
   search over the sorted keys — a cross-diagonal search, Alg. 2 again)
5. scatter token embeddings into (E, capacity, d); batched expert matmul;
   combine with router weights.

``moe_dispatch="cumsum"`` selects the conventional one-hot-cumsum
position computation as the ablation baseline (benchmarks table 2).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import merge_sort_kv
from repro.parallel.sharding import constrain
from .layers import dense_init, mlp_apply, mlp_init, _act


def moe_init(key, cfg: ModelConfig, dtype) -> Dict:
    d, e, fe = cfg.d_model, cfg.num_experts, cfg.d_ff
    keys = jax.random.split(key, 5)
    p = {
        "router": dense_init(keys[0], (d, e), d, jnp.float32),
        "wg": dense_init(keys[1], (e, d, fe), d, dtype),
        "wi": dense_init(keys[2], (e, d, fe), d, dtype),
        "wo": dense_init(keys[3], (e, fe, d), fe, dtype),
    }
    if cfg.shared_expert_ff:
        p["shared"] = mlp_init(keys[4], d, cfg.shared_expert_ff, "silu", dtype)
    return p


def capacity(cfg: ModelConfig, tokens_per_row: int) -> int:
    c = int(math.ceil(tokens_per_row * cfg.experts_per_token * cfg.capacity_factor / cfg.num_experts))
    return max(8, -(-c // 8) * 8)  # pad to lane-friendly multiple


def _positions_merge_path(flat_expert: jax.Array, e: int) -> Tuple[jax.Array, jax.Array]:
    """Merge-path dispatch: (position_in_expert, is_kept_order_rank) per slot.

    flat_expert: (N,) int32 expert ids (N = tokens*k).
    Returns position_in_expert (N,) aligned with the input slots.
    """
    n = flat_expert.shape[0]
    slots = jnp.arange(n, dtype=jnp.int32)
    sorted_e, sorted_slot = merge_sort_kv(flat_expert, slots)  # stable
    # expert start offsets within the sorted list: binary search (Alg. 2
    # against the "array" of expert ids — the same cross-diagonal search)
    offsets = jnp.searchsorted(sorted_e, jnp.arange(e, dtype=flat_expert.dtype), side="left")
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - offsets[sorted_e].astype(jnp.int32)
    # scatter positions back to original slot order
    pos = jnp.zeros((n,), jnp.int32).at[sorted_slot].set(pos_sorted)
    return pos


def _positions_cumsum(flat_expert: jax.Array, e: int) -> jax.Array:
    """Ablation baseline: one-hot cumsum position-in-expert (O(N*E))."""
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)  # (N,E)
    pos = jnp.cumsum(onehot, axis=0) - 1
    return jnp.take_along_axis(pos, flat_expert[:, None], axis=1)[:, 0]


def moe_apply(params: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x (B,S,d) -> (B,S,d). Batch axis stays sharded; experts tensor-sharded."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = capacity(cfg, s)
    router_logits = (x.astype(jnp.float32) @ params["router"])  # (B,S,E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (B,S,k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    def dispatch_row(xrow, erow, prow):
        # xrow (S,d), erow (S,k), prow (S,k)
        flat_e = erow.reshape(-1).astype(jnp.int32)  # (S*k,)
        if cfg.moe_dispatch == "merge_path":
            pos = _positions_merge_path(flat_e, e)
        else:
            pos = _positions_cumsum(flat_e, e)
        kept = pos < cap
        tok = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)
        # scatter embeddings into (E, cap, d); dropped slots go nowhere
        buf = jnp.zeros((e, cap, d), xrow.dtype)
        buf = buf.at[flat_e, jnp.where(kept, pos, cap)].set(
            xrow[tok], mode="drop"
        )
        return buf, (flat_e, pos, kept, tok)

    buf, (flat_e, pos, kept, tok) = jax.vmap(dispatch_row)(x, top_e, top_p)
    buf = constrain(buf, "act_batch", "act_experts", None, None)
    # batched expert MLP: (B,E,C,d) x (E,d,f) -> (B,E,C,f)
    up = jnp.einsum("becd,edf->becf", buf, params["wi"])
    gate = jnp.einsum("becd,edf->becf", buf, params["wg"])
    h = _act("silu", gate, up)
    h = constrain(h, "act_batch", "act_experts", None, None)
    out_buf = jnp.einsum("becf,efd->becd", h, params["wo"])  # (B,E,C,d)

    def combine_row(obuf, flat_e_r, pos_r, kept_r, tok_r, prow):
        # gather expert outputs back to token slots, weight, and sum over k
        vals = obuf[flat_e_r, jnp.minimum(pos_r, cap - 1)]  # (S*k, d)
        w = prow.reshape(-1)[:, None].astype(vals.dtype) * kept_r[:, None]
        y = jnp.zeros((s, d), vals.dtype).at[tok_r].add(vals * w)
        return y

    y = jax.vmap(combine_row)(out_buf, flat_e, pos, kept, tok, top_p)
    if "shared" in params:
        y = y + mlp_apply(params["shared"], x, "silu")
    return y.astype(x.dtype)


def aux_load_balance_loss(router_logits: jax.Array, top_e: jax.Array, e: int) -> jax.Array:
    """Switch-style load-balance auxiliary loss (available to train cfg)."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(probs.reshape(-1, e), axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(top_e.reshape(-1), e, dtype=jnp.float32), axis=0
    )
    return e * jnp.sum(me * ce)
