"""Shared neural-net layers (functional, params = pytrees of jnp arrays)."""

from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def make_rope(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """Rotary embedding tables for integer ``positions`` (any shape).

    Returns (sin, cos) with trailing dim head_dim//2, float32.
    """
    half = head_dim // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """Apply rotary embedding; x (..., heads, head_dim), sin/cos (..., head_dim//2).

    sin/cos broadcast over the heads axis (inserted at -2).
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s = sin[..., None, :]
    c = cos[..., None, :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(num_pos: int, d_model: int) -> jax.Array:
    """Whisper-style fixed sinusoidal position embeddings (num_pos, d)."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / max(1, half - 1))
    angles = jnp.arange(num_pos, dtype=jnp.float32)[:, None] * freqs
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


def _act(name: str, gate: Optional[jax.Array], up: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(gate) * up
    if name == "gelu_gated":
        return jax.nn.gelu(gate) * up
    if name == "gelu":
        return jax.nn.gelu(up)
    if name == "relu2":
        r = jax.nn.relu(up)
        return r * r
    raise ValueError(f"unknown act {name}")


def mlp_gated(name: str) -> bool:
    return name in ("silu", "gelu_gated")


def mlp_apply(params: Dict, x: jax.Array, act: str) -> jax.Array:
    """Dense MLP. x (..., d) -> (..., d)."""
    up = x @ params["wi"]
    gate = x @ params["wg"] if "wg" in params else None
    h = _act(act, gate, up)
    h = constrain(h, "act_batch", "act_seq", "act_ff")
    return h @ params["wo"]


def mlp_init(key, d_model: int, d_ff: int, act: str, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = 1.0 / math.sqrt(d_model)
    scale_out = 1.0 / math.sqrt(d_ff)
    p = {
        "wi": (jax.random.normal(k1, (d_model, d_ff), jnp.float32) * scale_in).astype(dtype),
        "wo": (jax.random.normal(k2, (d_ff, d_model), jnp.float32) * scale_out).astype(dtype),
    }
    if mlp_gated(act):
        p["wg"] = (jax.random.normal(k3, (d_model, d_ff), jnp.float32) * scale_in).astype(dtype)
    return p


def dense_init(key, shape, fan_in: int, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) / math.sqrt(fan_in)).astype(dtype)
