from .model import (
    init_params,
    abstract_params,
    forward_train,
    forward_prefill,
    forward_decode,
    init_caches,
    abstract_caches,
    encoder_forward,
)

__all__ = [
    "init_params",
    "abstract_params",
    "forward_train",
    "forward_prefill",
    "forward_decode",
    "init_caches",
    "abstract_caches",
    "encoder_forward",
]
