"""Decoder stacks for all 10 assigned architectures.

One functional implementation; families differ in the per-layer mixer
(attention / mamba / both) and FFN (dense MLP / merge-path MoE).  Layers
are scanned in homogeneous *groups* (``cfg.layer_group``): gemma3 scans
groups of 6 (5 sliding + 1 global), hymba groups of 8 (7 sliding + 1
global), everything else groups of 1 — keeping compiled HLO size
O(group), not O(L).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSM, HYBRID, VLM, AUDIO
from repro.parallel.sharding import constrain
from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import dense_init, mlp_apply, mlp_init, rms_norm, sinusoidal_positions


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _attn_init(key, cfg: ModelConfig, dtype) -> Dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d, h * hd), d, dtype),
        "wk": dense_init(k2, (d, kv * hd), d, dtype),
        "wv": dense_init(k3, (d, kv * hd), d, dtype),
        "wo": dense_init(k4, (h * hd, d), h * hd, dtype),
    }


def _layer_init(key, cfg: ModelConfig, dtype, cross: bool) -> Dict:
    d = cfg.d_model
    keys = jax.random.split(key, 6)
    p: Dict[str, Any] = {"attn_norm": jnp.zeros((d,), jnp.float32)}
    if cfg.family != SSM:
        p["attn"] = _attn_init(keys[0], cfg, dtype)
    if cfg.family in (SSM, HYBRID):
        p["mamba"] = ssm_mod.mamba_init(keys[1], cfg, dtype)
    if cross:
        p["cross"] = _attn_init(keys[2], cfg, dtype)
        p["cross_norm"] = jnp.zeros((d,), jnp.float32)
    if cfg.num_experts:
        p["moe"] = moe_mod.moe_init(keys[3], cfg, dtype)
        p["ffn_norm"] = jnp.zeros((d,), jnp.float32)
    elif cfg.d_ff:
        p["mlp"] = mlp_init(keys[4], d, cfg.d_ff, cfg.act, dtype)
        p["ffn_norm"] = jnp.zeros((d,), jnp.float32)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict:
    """Full parameter pytree (fp32 masters are handled by the optimizer)."""
    dtype = _dtype(cfg)
    d = cfg.d_model
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": {"table": dense_init(keys[0], (cfg.vocab_size, d), d, dtype)},
        "final_norm": jnp.zeros((d,), jnp.float32),
    }
    cross = cfg.is_encoder_decoder
    lkeys = jax.random.split(keys[1], cfg.num_layers)
    params["layers"] = jax.vmap(lambda k: _layer_init(k, cfg, dtype, cross))(lkeys)
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[2], (d, cfg.vocab_size), d, dtype)
    if cfg.num_prefix_tokens:
        params["prefix_proj"] = dense_init(keys[3], (d, d), d, dtype)
    if cfg.is_encoder_decoder:
        ekeys = jax.random.split(keys[4], cfg.encoder_layers)
        enc_cfg = cfg  # same dims
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _layer_init(k, enc_cfg, dtype, False))(ekeys),
            "final_norm": jnp.zeros((d,), jnp.float32),
            "frame_proj": dense_init(keys[5], (d, d), d, dtype),
        }
    return params


def abstract_params(cfg: ModelConfig) -> Dict:
    """ShapeDtypeStruct tree (no allocation) — dry-run / sharding planning."""
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))


# ---------------------------------------------------------------------------
# layer forward (train / prefill)
# ---------------------------------------------------------------------------

def _layer_fwd(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    window: int,
    kind: str,
    prefix_len: int,
    enc_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    collect_cache: bool = False,
    cache_len: int = 0,
):
    cache = {}
    h = rms_norm(x, p["attn_norm"], cfg.rms_eps)
    mix = jnp.zeros_like(x)
    if "attn" in p:
        akw = dict(
            num_heads=cfg.num_heads,
            num_kv=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta,
            positions=positions,
            kind=kind,
            window=window,
            prefix_len=prefix_len,
            chunk=cfg.attn_chunk,
            softcap=cfg.attn_logit_softcap,
            force_blockwise=cfg.train_attn_blockwise and x.shape[1] > 1024,
        )
        mix = mix + attn_mod.attention(p["attn"], h, **akw)
        if collect_cache:
            b, s, _ = h.shape
            kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            k = (h @ p["attn"]["wk"]).reshape(b, s, kv, hd)
            v = (h @ p["attn"]["wv"]).reshape(b, s, kv, hd)
            if cfg.rope_theta > 0:
                sin, cos = attn_mod.make_rope(positions, hd, cfg.rope_theta)
                k = attn_mod.apply_rope(k, sin, cos)
            w = window if window > 0 else 0
            clen = min(cache_len, w) if w else cache_len
            take = min(s, clen)
            kw = jnp.zeros((b, clen, kv, hd), k.dtype)
            slots = (positions[-take:] % clen) if w else positions[-take:] % max(clen, 1)
            kw = kw.at[:, slots].set(k[:, -take:])
            vw = jnp.zeros((b, clen, kv, hd), v.dtype)
            vw = vw.at[:, slots].set(v[:, -take:])
            cache["k"], cache["v"] = kw, vw
    if "mamba" in p:
        if collect_cache:
            y, conv_st, ssm_st = _mamba_with_state(p["mamba"], h, cfg)
            cache["conv"], cache["ssm"] = conv_st, ssm_st
            mix = mix + y
        else:
            mix = mix + ssm_mod.mamba_forward(p["mamba"], h, cfg)
    x = x + mix
    if "cross" in p and enc_kv is not None:
        hc = rms_norm(x, p["cross_norm"], cfg.rms_eps)
        x = x + attn_mod.attention(
            p["cross"],
            hc,
            num_heads=cfg.num_heads,
            num_kv=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim,
            rope_theta=0.0,
            positions=positions,
            kv=enc_kv,
        )
    if "ffn_norm" in p:
        hf = rms_norm(x, p["ffn_norm"], cfg.rms_eps)
        if "moe" in p:
            x = x + moe_mod.moe_apply(p["moe"], hf, cfg)
        else:
            x = x + mlp_apply(p["mlp"], hf, cfg.act)
    return x, cache


def _mamba_with_state(p, h, cfg):
    """mamba_forward that also returns final (conv, ssm) states for caching."""
    b, s, d = h.shape
    di, st, r = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    xz = h @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, _ = ssm_mod._causal_conv(xin, p["conv_w"], None)
    kk = cfg.ssm_conv
    conv_state = xin[:, -(kk - 1) :] if s >= kk - 1 else jnp.pad(xin, ((0, 0), (kk - 1 - s, 0), (0, 0)))
    xc = jax.nn.silu(xc)
    proj = xc @ p["x_proj"]
    dt_r, bmat, cmat = jnp.split(proj, [r, r + st], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"] + p["dt_bias"]).astype(jnp.float32)
    a = -jnp.exp(p["A_log"])
    y, h_final = ssm_mod.ssm_apply(dt, xc, bmat, cmat, a, cfg)
    y = (y + p["D"][None, None] * xc.astype(jnp.float32)).astype(h.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], conv_state, h_final


def _group_windows(cfg: ModelConfig):
    """Per-sublayer sliding window (0 = global) inside one scan group."""
    gp = cfg.layer_group
    if gp == 1:
        return (cfg.sliding_window,) if cfg.sliding_window and not cfg.global_every else (0,)
    return tuple(cfg.sliding_window if i < gp - 1 else 0 for i in range(gp))


def _stack_params(cfg: ModelConfig, layers: Dict):
    gp = cfg.layer_group
    ng = cfg.num_layers // gp
    return jax.tree.map(lambda t: t.reshape(ng, gp, *t.shape[1:]), layers)


def stack_forward(
    cfg: ModelConfig,
    layers: Dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    kind: str = "causal",
    prefix_len: int = 0,
    enc_kv_layers=None,  # (L, B, Senc, K, hd) x2 for enc-dec decoders
    collect_caches: bool = False,
    cache_len: int = 0,
):
    """Scan the layer stack; optionally collect decode caches."""
    gp = cfg.layer_group
    windows = _group_windows(cfg)
    stacked = _stack_params(cfg, layers)
    if enc_kv_layers is not None:
        ek, ev = enc_kv_layers
        ng = cfg.num_layers // gp
        ek = ek.reshape(ng, gp, *ek.shape[1:])
        ev = ev.reshape(ng, gp, *ev.shape[1:])
        xs = (stacked, ek, ev)
    else:
        xs = (stacked,)

    def body(xcarry, xs_g):
        if enc_kv_layers is not None:
            gparams, ekg, evg = xs_g
        else:
            (gparams,) = xs_g
            ekg = evg = None
        caches_g = {}
        for i in range(gp):
            p_i = jax.tree.map(lambda t: t[i], gparams)
            enc_kv = (ekg[i], evg[i]) if ekg is not None else None
            xcarry, cache = _layer_fwd(
                cfg,
                p_i,
                xcarry,
                positions,
                window=windows[i],
                kind=kind,
                prefix_len=prefix_len,
                enc_kv=enc_kv,
                collect_cache=collect_caches,
                cache_len=cache_len,
            )
            if collect_caches:
                caches_g[f"sub{i}"] = cache
        return xcarry, caches_g if collect_caches else None

    if cfg.remat:
        if cfg.remat_policy == "dots":
            body_fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            )
        else:
            body_fn = jax.checkpoint(body)
    else:
        body_fn = body
    from repro.utils.costmode import scan_unroll

    ng = cfg.num_layers // gp
    x, caches = jax.lax.scan(body_fn, x, xs, unroll=scan_unroll(ng))
    return x, caches
