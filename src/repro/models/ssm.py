"""Mamba-1 selective SSM block (falcon-mamba, hymba's mamba heads).

TPU adaptation: the CUDA "hardware-aware" kernel (fused recurrent scan in
SRAM) becomes, per ``cfg.ssm_backend``:

* ``"scan"`` — a **chunked associative scan**: ``lax.scan`` over sequence
  chunks (bounding materialized state to one chunk) with a parallel
  ``lax.associative_scan`` inside each chunk (log-depth on the VPU).  The
  (decay, update) pairs form the standard linear-recurrence monoid
  ``(a2, b2) ∘ (a1, b1) = (a1*a2, b1*a2 + b2)``.
* ``"fused"`` — the Pallas VMEM kernel
  (:func:`repro.kernels.ssm_scan.ssm_scan_pallas`): the recurrence state
  never touches HBM and the (B, S, d_inner, state) decay/update tensors
  are never materialized at all.  The kernel carries a chunk-recompute
  ``jax.custom_vjp``, so this backend trains — ``jax.grad`` through
  ``forward_train`` runs the recompute backward kernel, no oracle-route
  fallback.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import constrain
from .layers import dense_init


def mamba_init(key, cfg: ModelConfig, dtype) -> Dict:
    d, di, st, r, k = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    keys = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(keys[0], (d, 2 * di), d, dtype),
        "conv_w": dense_init(keys[1], (di, k), k, dtype),
        "x_proj": dense_init(keys[2], (di, r + 2 * st), di, dtype),
        "dt_proj": dense_init(keys[3], (r, di), r, dtype),
        "dt_bias": jnp.zeros((di,), dtype),
        "A_log": jnp.log(a).astype(jnp.float32),  # kept f32 (exp of it is sensitive)
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(keys[4], (di, d), di, dtype),
    }


def _ssm_scan_chunked(decay: jax.Array, upd: jax.Array, h0: jax.Array, chunk: int):
    """Linear recurrence h_t = decay_t * h_{t-1} + upd_t, chunked.

    decay/upd (B, S, di, st) f32; h0 (B, di, st).  Returns (ys (B,S,di,st), h_final).
    """
    b, s, di, st = decay.shape
    from repro.utils.costmode import cost_exact

    if cost_exact():
        # one associative scan over the whole sequence: loop-free HLO so
        # cost_analysis is exact (the chunked form hides trips in a While)
        chunk = s
    chunk = min(chunk, s)
    nchunks = -(-s // chunk)
    pad = nchunks * chunk - s
    if pad:
        # identity steps: decay 1, update 0 — h_final is preserved
        decay = jnp.pad(decay, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        upd = jnp.pad(upd, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dec_c = decay.reshape(b, nchunks, chunk, di, st).transpose(1, 0, 2, 3, 4)
    upd_c = upd.reshape(b, nchunks, chunk, di, st).transpose(1, 0, 2, 3, 4)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def step(h, xs):
        dec, up = xs  # (B, chunk, di, st)
        a_cum, b_cum = jax.lax.associative_scan(combine, (dec, up), axis=1)
        ys = a_cum * h[:, None] + b_cum
        return ys[:, -1], ys

    from repro.utils.costmode import scan_unroll

    h_final, ys = jax.lax.scan(step, h0, (dec_c, upd_c), unroll=scan_unroll(nchunks))
    ys = ys.transpose(1, 0, 2, 3, 4).reshape(b, nchunks * chunk, di, st)[:, :s]
    return ys, h_final


def ssm_apply(
    dt: jax.Array,  # (B,S,di) f32 (post-softplus step sizes)
    xc: jax.Array,  # (B,S,di) conv+silu activations
    bmat: jax.Array,  # (B,S,st)
    cmat: jax.Array,  # (B,S,st)
    a: jax.Array,  # (di,st) f32, negative
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array]:
    """Selective-scan core shared by :func:`mamba_forward` and the hybrid
    block; returns ``(y (B,S,di) f32 = Σ_s h·C, h_final (B,di,st) f32)``.

    ``cfg.ssm_backend == "fused"`` routes through the differentiable
    Pallas kernel (state VMEM-resident, decay/update tensors never
    materialized, chunk-recompute backward); ``"scan"`` materializes the
    (B,S,di,st) decay/update pairs in ``cfg.ssm_scan_dtype`` and runs the
    chunked associative scan.
    """
    b, s, di = xc.shape
    st = bmat.shape[-1]
    if cfg.ssm_backend == "fused":
        from repro.kernels.ssm_scan import ssm_scan_pallas

        y, h_final = ssm_scan_pallas(dt, xc, bmat, cmat, a, chunk=cfg.ssm_chunk)
        return y.astype(jnp.float32), h_final
    sdt = jnp.dtype(cfg.ssm_scan_dtype)
    decay = jnp.exp(dt[..., None] * a[None, None]).astype(sdt)  # (B,S,di,st)
    upd = ((dt[..., None] * bmat.astype(jnp.float32)[:, :, None, :])
           * xc.astype(jnp.float32)[..., None]).astype(sdt)
    h0 = jnp.zeros((b, di, st), sdt)
    hs, h_final = _ssm_scan_chunked(decay, upd, h0, cfg.ssm_chunk)
    hs = hs.astype(jnp.float32)
    y = jnp.sum(hs * cmat.astype(jnp.float32)[:, :, None, :], axis=-1)  # (B,S,di)
    return y, h_final.astype(jnp.float32)


def _causal_conv(x: jax.Array, w: jax.Array, state: jax.Array | None):
    """Depthwise causal conv; x (B,S,di), w (di,k), state (B,k-1,di) or None."""
    k = w.shape[1]
    if state is None:
        xpad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xpad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    # windowed sum: out_t = sum_i w[:, i] * xpad[:, t + i]
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xpad[:, i : i + x.shape[1]] * w[None, None, :, i]
    new_state = xpad[:, -(k - 1) :] if k > 1 else None
    return out, new_state


def mamba_forward(
    params: Dict, x: jax.Array, cfg: ModelConfig
) -> jax.Array:
    """Training/prefill form; x (B,S,d) -> (B,S,d)."""
    b, s, d = x.shape
    di, st, r = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    xz = x @ params["in_proj"]  # (B,S,2di)
    xz = constrain(xz, "act_batch", "act_seq", "act_ff")
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, _ = _causal_conv(xin, params["conv_w"], None)
    xc = jax.nn.silu(xc)
    proj = xc @ params["x_proj"]  # (B,S,r+2st)
    dt_r, bmat, cmat = jnp.split(proj, [r, r + st], axis=-1)
    dt = jax.nn.softplus(dt_r @ params["dt_proj"] + params["dt_bias"]).astype(jnp.float32)
    a = -jnp.exp(params["A_log"])  # (di, st)
    y, _ = ssm_apply(dt, xc, bmat, cmat, a, cfg)  # (B,S,di) f32
    y = (y + params["D"][None, None] * xc.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = constrain(y, "act_batch", "act_seq", "act_ff")
    return y @ params["out_proj"]


def mamba_decode(
    params: Dict,
    x: jax.Array,  # (B,1,d)
    conv_state: jax.Array,  # (B, k-1, di)
    ssm_state: jax.Array,  # (B, di, st) f32
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """O(1) decode step; returns (out (B,1,d), conv_state', ssm_state')."""
    b = x.shape[0]
    di, st, r = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)  # (B,1,di)
    xc, new_conv = _causal_conv(xin, params["conv_w"], conv_state)
    xc = jax.nn.silu(xc)[:, 0]  # (B,di)
    proj = xc @ params["x_proj"]
    dt_r, bmat, cmat = jnp.split(proj, [r, r + st], axis=-1)
    dt = jax.nn.softplus(dt_r @ params["dt_proj"] + params["dt_bias"]).astype(jnp.float32)
    a = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt[..., None] * a[None])  # (B,di,st)
    upd = (dt[..., None] * bmat.astype(jnp.float32)[:, None, :]) * xc.astype(jnp.float32)[..., None]
    h = decay * ssm_state + upd
    y = jnp.sum(h * cmat.astype(jnp.float32)[:, None, :], axis=-1)  # (B,di)
    y = (y + params["D"][None] * xc.astype(jnp.float32)).astype(x.dtype)
    y = (y * jax.nn.silu(z[:, 0]))[:, None]  # (B,1,di)
    return y @ params["out_proj"], new_conv, h


def mamba_init_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }
