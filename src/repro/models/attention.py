"""Attention: full / blockwise (online-softmax) / sliding-window / decode.

All variants share one set of projections; the score/softmax path is
chosen by sequence length and window config so that every assigned
shape cell lowers with bounded live memory:

* ``seq <= full_threshold``: dense masked attention (train_4k).
* longer: blockwise attention — ``lax.scan`` over KV chunks with a
  running (max, denom, acc) online softmax (prefill_32k).
* ``window > 0``: sliding-window mask (and a ring-buffer cache on the
  decode path), used by gemma3 local layers and hymba.
* decode: single-query attention over a cache; optionally
  context-parallel over the ``model`` axis (see serving.engine).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain
from .layers import apply_rope, make_rope

NEG_INF = -1e30
FULL_ATTENTION_THRESHOLD = 8192


def qkv_proj(params: Dict, x: jax.Array, num_heads: int, num_kv: int, head_dim: int):
    """x (B,S,d) -> q (B,S,H,hd), k/v (B,S,K,hd)."""
    b, s, _ = x.shape
    q = (x @ params["wq"]).reshape(b, s, num_heads, head_dim)
    k = (x @ params["wk"]).reshape(b, s, num_kv, head_dim)
    v = (x @ params["wv"]).reshape(b, s, num_kv, head_dim)
    q = constrain(q, "act_batch", "act_seq", "act_heads", None)
    k = constrain(k, "act_batch", "act_seq", None, None)
    v = constrain(v, "act_batch", "act_seq", None, None)
    return q, k, v


def _mask(
    qpos: jax.Array,  # (Sq,) absolute positions of queries
    kpos: jax.Array,  # (Sk,)
    kind: str,  # causal | full | prefix
    window: int,
    prefix_len: int,
) -> jax.Array:
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if kind == "causal" or kind == "prefix":
        m = kpos[None, :] <= qpos[:, None]
        if kind == "prefix":
            m = m | (kpos[None, :] < prefix_len)
    if window > 0:
        m = m & (kpos[None, :] > qpos[:, None] - window)
    return m


def _sdpa(q, k, v, mask, softcap: float) -> jax.Array:
    """q (B,Sq,K,G,hd), k/v (B,Sk,K,hd), mask (Sq,Sk) -> (B,Sq,K,G,hd)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32) / math.sqrt(hd)
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", probs, v)


def _blockwise(q, k, v, qpos, kind, window, prefix_len, chunk, softcap) -> jax.Array:
    """Online-softmax over KV chunks; q (B,Sq,K,G,hd), k/v (B,Sk,K,hd)."""
    b, sq, kh, g, hd = q.shape
    sk = k.shape[1]
    from repro.utils.costmode import cost_exact

    if cost_exact():
        # bound unrolled chunk count: flops identical, compile stays small
        chunk = max(chunk, -(-sk // 8))
    nchunks = -(-sk // chunk)
    pad = nchunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, nchunks, chunk, kh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunks, chunk, kh, hd).transpose(1, 0, 2, 3, 4)
    qf = q.astype(jnp.float32)

    def step(carry, xs):
        m_run, l_run, acc = carry
        kb, vb, ci = xs
        kpos = ci * chunk + jnp.arange(chunk)
        msk = _mask(qpos, kpos, kind, window, prefix_len) & (kpos < sk)[None, :]
        s = jnp.einsum("bqkgh,bskh->bkgqs", qf, kb.astype(jnp.float32)) / math.sqrt(hd)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m_run - m_new)
        l_new = l_run * scale + jnp.sum(p, axis=-1)
        acc = acc * scale[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kh, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, kh, g, sq, hd), jnp.float32)
    from repro.utils.costmode import scan_unroll

    (m_f, l_f, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (kc, vc, jnp.arange(nchunks)), unroll=scan_unroll(nchunks)
    )
    out = acc / jnp.maximum(l_f[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (B,Sq,K,G,hd)


def attention(
    params: Dict,
    x: jax.Array,
    *,
    num_heads: int,
    num_kv: int,
    head_dim: int,
    rope_theta: float,
    positions: jax.Array,  # (S,) absolute positions
    kind: str = "causal",
    window: int = 0,
    prefix_len: int = 0,
    chunk: int = 1024,
    softcap: float = 0.0,
    kv: Optional[Tuple[jax.Array, jax.Array]] = None,  # cross-attention K/V source
    force_blockwise: bool = False,
) -> jax.Array:
    """Self (or cross, if kv given) attention; x (B,S,d) -> (B,S,d)."""
    b, s, d = x.shape
    g = num_heads // num_kv
    if kv is None:
        q, k, v = qkv_proj(params, x, num_heads, num_kv, head_dim)
        if rope_theta > 0:
            sin, cos = make_rope(positions, head_dim, rope_theta)
            q = apply_rope(q, sin, cos)
            k = apply_rope(k, sin, cos)
        kpos = positions
    else:
        k, v = kv  # (B,Sk,K,hd) precomputed (encoder output projections)
        q = (x @ params["wq"]).reshape(b, s, num_heads, head_dim)
        kind = "full"
        kpos = jnp.arange(k.shape[1])
    qh = q.reshape(b, s, num_kv, g, head_dim)
    if k.shape[1] <= FULL_ATTENTION_THRESHOLD and not force_blockwise:
        mask = _mask(positions, kpos, kind, window, prefix_len)
        out = _sdpa(qh, k, v, mask, softcap)
    else:
        out = _blockwise(qh, k, v, positions, kind, window, prefix_len, chunk, softcap)
    out = out.reshape(b, s, num_heads * head_dim)
    out = constrain(out, "act_batch", "act_seq", "act_heads")
    return out @ params["wo"]


# ---------------------------------------------------------------------------
# decode path (single new token against a cache)
# ---------------------------------------------------------------------------

def decode_attention(
    params: Dict,
    x: jax.Array,  # (B, 1, d)
    cache_k: jax.Array,  # (B, S_cache, K, hd) — ring buffer if window > 0
    cache_v: jax.Array,
    pos: jax.Array,  # (B,) absolute position of the new token
    *,
    num_heads: int,
    num_kv: int,
    head_dim: int,
    rope_theta: float,
    window: int = 0,
    softcap: float = 0.0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (out (B,1,d), new_cache_k, new_cache_v)."""
    b = x.shape[0]
    s_cache = cache_k.shape[1]
    g = num_heads // num_kv
    q = (x @ params["wq"]).reshape(b, num_heads, head_dim)
    k = (x @ params["wk"]).reshape(b, num_kv, head_dim)
    v = (x @ params["wv"]).reshape(b, num_kv, head_dim)
    if rope_theta > 0:
        sin, cos = make_rope(pos[:, None], head_dim, rope_theta)  # (B,1,half)
        q = apply_rope(q.reshape(b, 1, num_heads, head_dim), sin, cos).reshape(b, num_heads, head_dim)
        k = apply_rope(k.reshape(b, 1, num_kv, head_dim), sin, cos).reshape(b, num_kv, head_dim)
    if window > 0:
        slot = pos % s_cache
    else:
        slot = jnp.minimum(pos, s_cache - 1)
    bidx = jnp.arange(b)
    cache_k = cache_k.at[bidx, slot].set(k.astype(cache_k.dtype))
    cache_v = cache_v.at[bidx, slot].set(v.astype(cache_v.dtype))
    cache_k = constrain(cache_k, "act_batch", "act_kv_seq", None, None)
    cache_v = constrain(cache_v, "act_batch", "act_kv_seq", None, None)
    # absolute position held by each cache slot
    ridx = jnp.arange(s_cache)[None, :]
    if window > 0:
        kpos = pos[:, None] - ((pos[:, None] - ridx) % s_cache)
    else:
        kpos = ridx * jnp.ones((b, 1), jnp.int32)
    valid = (kpos <= pos[:, None]) & (kpos >= 0)
    if window > 0:
        valid = valid & (kpos > pos[:, None] - window)
    qh = q.reshape(b, num_kv, g, head_dim)
    scores = jnp.einsum("bkgh,bskh->bkgs", qh, cache_k).astype(jnp.float32) / math.sqrt(head_dim)
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, cache_v)
    out = out.reshape(b, 1, num_heads * head_dim)
    return out @ params["wo"], cache_k, cache_v


def cross_decode_attention(params, x, xk, xv, *, num_heads, num_kv, head_dim):
    """Cross-attention for one decode step; xk/xv (B,Senc,K,hd)."""
    b = x.shape[0]
    g = num_heads // num_kv
    q = (x @ params["wq"]).reshape(b, num_kv, g, head_dim)
    scores = jnp.einsum("bkgh,bskh->bkgs", q, xk).astype(jnp.float32) / math.sqrt(head_dim)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, xv).reshape(b, 1, num_heads * head_dim)
    return out @ params["wo"]
