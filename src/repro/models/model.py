"""Top-level model API: embed -> stack -> logits, all modes, all families."""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSM, HYBRID, VLM, AUDIO
from repro.parallel.sharding import constrain
from . import attention as attn_mod
from . import ssm as ssm_mod
from .layers import rms_norm, sinusoidal_positions
from .transformer import (
    _dtype,
    _group_windows,
    _stack_params,
    init_params,
    abstract_params,
    stack_forward,
)

__all__ = [
    "init_params",
    "abstract_params",
    "forward_train",
    "forward_prefill",
    "forward_decode",
    "init_caches",
    "encoder_forward",
]


def _embed(cfg: ModelConfig, params: Dict, tokens: jax.Array) -> jax.Array:
    x = params["embed"]["table"][tokens]
    x = x * jnp.sqrt(jnp.array(cfg.d_model, x.dtype))
    return constrain(x, "act_batch", "act_seq", "act_embed")


def _logits(cfg: ModelConfig, params: Dict, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
    else:
        logits = x @ params["unembed"]
    return constrain(logits, "act_batch", "act_seq", "act_vocab")


def encoder_forward(cfg: ModelConfig, params: Dict, frames: jax.Array):
    """Whisper encoder over precomputed frame embeddings (stub frontend).

    frames (B, S_enc, d) -> per-decoder-layer cross K/V (L, B, S_enc, K, hd).
    """
    enc = params["encoder"]
    x = frames @ enc["frame_proj"]
    x = x + sinusoidal_positions(frames.shape[1], cfg.d_model).astype(x.dtype)[None]
    positions = jnp.arange(frames.shape[1])
    # encoder scan reuses the decoder group machinery with kind=full
    import dataclasses

    enc_cfg = dataclasses.replace(
        cfg, num_layers=cfg.encoder_layers, global_every=0, sliding_window=0,
        num_experts=0, experts_per_token=0,
    )
    x, _ = stack_forward(enc_cfg, enc["layers"], x, positions, kind="full")
    x = rms_norm(x, enc["final_norm"], cfg.rms_eps)
    # per-decoder-layer cross projections
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    b, s, _ = x.shape

    def proj(cross_p):
        k = (x @ cross_p["wk"]).reshape(b, s, kv, hd)
        v = (x @ cross_p["wv"]).reshape(b, s, kv, hd)
        return k, v

    ek, ev = jax.vmap(proj)(params["layers"]["cross"])  # (L,B,S,K,hd)
    return ek, ev


def _prepare_inputs(cfg: ModelConfig, params: Dict, batch: Dict):
    """Embed tokens (+ modality prefixes); returns (x, positions, kind, prefix_len, enc_kv)."""
    kind = "causal"
    prefix_len = 0
    enc_kv = None
    if cfg.family == AUDIO:
        enc_kv = encoder_forward(cfg, params, batch["frames"])
        x = _embed(cfg, params, batch["tokens"])
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]
        positions = jnp.arange(x.shape[1])
        return x, positions, kind, prefix_len, enc_kv
    if cfg.family == VLM and "prefix_emb" in batch:
        pre = (batch["prefix_emb"] @ params["prefix_proj"]).astype(_dtype(cfg))
        tok = _embed(cfg, params, batch["tokens"])
        x = jnp.concatenate([pre, tok], axis=1)
        kind = "prefix"
        prefix_len = pre.shape[1]
    else:
        x = _embed(cfg, params, batch["tokens"])
    positions = jnp.arange(x.shape[1])
    return x, positions, kind, prefix_len, enc_kv


def forward_train(cfg: ModelConfig, params: Dict, batch: Dict) -> jax.Array:
    """Returns logits aligned with batch['labels']."""
    x, positions, kind, prefix_len, enc_kv = _prepare_inputs(cfg, params, batch)
    x, _ = stack_forward(
        cfg, params["layers"], x, positions, kind=kind, prefix_len=prefix_len,
        enc_kv_layers=enc_kv,
    )
    if cfg.family == VLM and prefix_len:
        x = x[:, prefix_len:]
    return _logits(cfg, params, x)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, cache_len: int) -> Dict:
    """Zero caches for decode: {'sub<i>': tree with leading (num_groups,)}."""
    dtype = _dtype(cfg)
    gp = cfg.layer_group
    ng = cfg.num_layers // gp
    windows = _group_windows(cfg)
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    caches: Dict[str, Any] = {}
    for i in range(gp):
        sub: Dict[str, Any] = {}
        if cfg.family != SSM:
            w = windows[i]
            clen = min(cache_len, w) if w else cache_len
            sub["k"] = jnp.zeros((ng, batch, clen, kv, hd), dtype)
            sub["v"] = jnp.zeros((ng, batch, clen, kv, hd), dtype)
        if cfg.family in (SSM, HYBRID):
            sub["conv"] = jnp.zeros((ng, batch, cfg.ssm_conv - 1, cfg.d_inner), dtype)
            sub["ssm"] = jnp.zeros((ng, batch, cfg.d_inner, cfg.ssm_state), jnp.float32)
        caches[f"sub{i}"] = sub
    return caches


def abstract_caches(cfg: ModelConfig, batch: int, cache_len: int):
    return jax.eval_shape(lambda: init_caches(cfg, batch, cache_len))


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def forward_prefill(cfg: ModelConfig, params: Dict, batch: Dict, cache_len: int = 0):
    """Run the prompt; returns (last-position logits, caches, [enc_kv])."""
    x, positions, kind, prefix_len, enc_kv = _prepare_inputs(cfg, params, batch)
    cache_len = cache_len or x.shape[1]
    x, caches = stack_forward(
        cfg, params["layers"], x, positions, kind=kind, prefix_len=prefix_len,
        enc_kv_layers=enc_kv, collect_caches=True, cache_len=cache_len,
    )
    logits = _logits(cfg, params, x[:, -1:])[:, 0]
    return logits, caches, enc_kv


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _layer_decode(cfg: ModelConfig, p: Dict, x, cache: Dict, pos, window: int, enc_kv):
    new_cache: Dict[str, Any] = {}
    h = rms_norm(x, p["attn_norm"], cfg.rms_eps)
    mix = jnp.zeros_like(x)
    if "attn" in p:
        out, ck, cv = attn_mod.decode_attention(
            p["attn"], h, cache["k"], cache["v"], pos,
            num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            window=window, softcap=cfg.attn_logit_softcap,
        )
        mix = mix + out
        new_cache["k"], new_cache["v"] = ck, cv
    if "mamba" in p:
        y, conv_st, ssm_st = ssm_mod.mamba_decode(
            p["mamba"], h, cache["conv"], cache["ssm"], cfg
        )
        mix = mix + y
        new_cache["conv"], new_cache["ssm"] = conv_st, ssm_st
    x = x + mix
    if "cross" in p and enc_kv is not None:
        hc = rms_norm(x, p["cross_norm"], cfg.rms_eps)
        x = x + attn_mod.cross_decode_attention(
            p["cross"], hc, enc_kv[0], enc_kv[1],
            num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim,
        )
    if "ffn_norm" in p:
        hf = rms_norm(x, p["ffn_norm"], cfg.rms_eps)
        if "moe" in p:
            from . import moe as moe_mod

            x = x + moe_mod.moe_apply(p["moe"], hf, cfg)
        else:
            from .layers import mlp_apply

            x = x + mlp_apply(p["mlp"], hf, cfg.act)
    return x, new_cache


def forward_decode(
    cfg: ModelConfig,
    params: Dict,
    caches: Dict,
    token: jax.Array,  # (B, 1) int32
    pos: jax.Array,  # (B,) absolute position of `token`
    enc_kv=None,  # (L,B,Senc,K,hd) x2 for enc-dec
) -> Tuple[jax.Array, Dict]:
    """One decode step; returns (logits (B,V), new caches)."""
    x = _embed(cfg, params, token)
    if cfg.family == AUDIO:
        # absolute sinusoidal position for the new token
        table = sinusoidal_positions(int(caches["sub0"]["k"].shape[2]) + 1, cfg.d_model)
        x = x + table[pos][:, None].astype(x.dtype)
    gp = cfg.layer_group
    windows = _group_windows(cfg)
    stacked = _stack_params(cfg, params["layers"])
    xs = [stacked, caches]
    if enc_kv is not None:
        ng = cfg.num_layers // gp
        ek = enc_kv[0].reshape(ng, gp, *enc_kv[0].shape[1:])
        ev = enc_kv[1].reshape(ng, gp, *enc_kv[1].shape[1:])
        xs.append((ek, ev))

    def body(xcarry, xs_g):
        if enc_kv is not None:
            gparams, caches_g, (ekg, evg) = xs_g
        else:
            gparams, caches_g = xs_g
            ekg = evg = None
        new_g = {}
        for i in range(gp):
            p_i = jax.tree.map(lambda t: t[i], gparams)
            ekv = (ekg[i], evg[i]) if ekg is not None else None
            xcarry, nc = _layer_decode(cfg, p_i, xcarry, caches_g[f"sub{i}"], pos, windows[i], ekv)
            new_g[f"sub{i}"] = nc
        return xcarry, new_g

    from repro.utils.costmode import scan_unroll

    ng = cfg.num_layers // gp
    x, new_caches = jax.lax.scan(body, x, tuple(xs), unroll=scan_unroll(ng))
    logits = _logits(cfg, params, x)[:, 0]
    return logits, new_caches
