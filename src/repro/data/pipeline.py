"""Deterministic synthetic data pipeline with merge-path length packing.

Production posture: per-host deterministic shards (seed, host_id, step) —
restartable at any step without coordination (fault-tolerance: after a
restore to step k, ``batch_at(k)`` regenerates exactly the batch the
failed run would have seen).  Documents have a synthetic length
distribution; batches are assembled with **length-sorted packing**: the
per-batch document pool is sorted by length with the merge-path sort and
greedily packed into rows, minimizing pad FLOPs (integration #3 of the
paper's technique, see DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import merge_sort_kv


@dataclasses.dataclass
class PipelineConfig:
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    pack: bool = True
    mean_doc_len: int = 512


class SyntheticLMPipeline:
    """Yields {'tokens','labels'} batches; infinitely indexable by step."""

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int, pcfg: PipelineConfig):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.pcfg = pcfg

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.pcfg.seed * 1_000_003 + self.pcfg.host_id) * 1_000_003 + step
        )

    def _doc_lengths(self, rng: np.random.Generator, n: int) -> np.ndarray:
        lens = rng.geometric(1.0 / self.pcfg.mean_doc_len, size=n).clip(8, self.seq_len)
        return lens.astype(np.int32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        b, s = self.batch, self.seq_len
        if not self.pcfg.pack:
            toks = rng.integers(1, self.cfg.vocab_size, size=(b, s + 1), dtype=np.int64)
            return {
                "tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32),
            }
        # --- merge-path length-sorted packing ---
        pool = self._doc_lengths(rng, 2 * b * max(1, s // self.pcfg.mean_doc_len))
        order = np.asarray(
            merge_sort_kv(jnp.asarray(-pool), jnp.arange(pool.shape[0], dtype=jnp.int32))[1]
        )
        rows = np.full((b, s + 1), 0, dtype=np.int64)
        row_fill = np.zeros(b, dtype=np.int64)
        # longest-first first-fit: sorted order makes this near-optimal
        for di in order:
            L = int(pool[di])
            target = int(np.argmin(row_fill))
            if row_fill[target] + L > s + 1:
                continue
            seg = rng.integers(1, self.cfg.vocab_size, size=L, dtype=np.int64)
            rows[target, row_fill[target] : row_fill[target] + L] = seg
            row_fill[target] += L
            if row_fill.min() >= s + 1:
                break
        labels = rows[:, 1:].copy()
        labels[labels == 0] = -1  # mask padding
        return {"tokens": rows[:, :-1].astype(np.int32), "labels": labels.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Host-side description of one batch (used by input_specs)."""
    return {"tokens": (shape.global_batch, shape.seq_len), "labels": (shape.global_batch, shape.seq_len)}
