"""Cost-exact scan mode.

XLA's ``cost_analysis`` counts a while-loop body ONCE, not multiplied by
the trip count, so any model that scans over layers / KV chunks / SSM
chunks under-reports FLOPs and bytes.  For roofline measurement the
dry-run compiles *shallow depth variants* with every ``lax.scan`` fully
unrolled (this flag), measures them, and extrapolates linearly in depth.
The production (full-depth) compile keeps rolled scans.
"""

_EXACT = False


def set_cost_exact(value: bool) -> None:
    global _EXACT
    _EXACT = bool(value)


def cost_exact() -> bool:
    return _EXACT


def scan_unroll(length: int) -> int:
    """Pass as lax.scan(..., unroll=scan_unroll(length))."""
    return length if _EXACT else 1
