import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract memory / cost / roofline terms.

The two lines above MUST stay first: jax locks the device count at first
initialization, and the production meshes need 512 placeholder devices.
Smoke tests and benchmarks do NOT import this module.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""

import argparse
import json
import math
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (
    SHAPES,
    SHAPES_BY_NAME,
    TrainConfig,
    get_config,
    list_archs,
    shape_applicable,
)
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    batch_shardings,
    batch_specs,
    decode_shardings,
    decode_specs,
    params_shardings,
    state_shardings,
)
from repro.parallel.sharding import make_rules, sharding_env
from repro.train.steps import make_decode_step, make_prefill_step, make_train_step


def _depth_variant(cfg, num_layers: int):
    """Same architecture at reduced depth (used for cost extrapolation)."""
    import dataclasses

    kw = {"num_layers": num_layers}
    if cfg.encoder_layers:
        kw["encoder_layers"] = num_layers
    return dataclasses.replace(cfg, **kw)


def _compile_one(cfg, shape, multi_pod: bool, tcfg: TrainConfig):
    """Lower + compile one concrete config; returns (compiled, t_lower, t_compile)."""
    import dataclasses

    mesh = make_production_mesh(multi_pod=multi_pod)
    context_parallel = shape.mode == "decode"
    rules = make_rules(mesh, fsdp_over_pod=cfg.fsdp_over_pod, context_parallel=context_parallel)
    # batch-1 (long-context) cells cannot shard the batch axis — replicate it
    batch_degree = math.prod(dict(zip(mesh.axis_names, mesh.devices.shape))[a] for a in rules.batch)
    if shape.global_batch % batch_degree != 0:
        rules = dataclasses.replace(rules, batch=())

    t0 = time.time()
    with sharding_env(mesh, rules):
        if shape.mode == "train":
            state, st_sh = state_shardings(cfg, tcfg, mesh, rules)
            batch = batch_specs(cfg, shape, "train")
            b_sh = batch_shardings(cfg, shape, "train", mesh, rules)
            step = make_train_step(cfg, tcfg)
            jitted = jax.jit(step, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state, batch)
        elif shape.mode == "prefill":
            params, p_sh = params_shardings(cfg, mesh, rules)
            batch = batch_specs(cfg, shape, "prefill")
            b_sh = batch_shardings(cfg, shape, "prefill", mesh, rules)
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params, batch)
        else:  # decode
            params, p_sh = params_shardings(cfg, mesh, rules)
            dspec = decode_specs(cfg, shape)
            d_sh = decode_shardings(cfg, shape, mesh, rules, dspec["caches"])
            step = make_decode_step(cfg)
            args = (params, dspec["caches"], dspec["token"], dspec["pos"])
            shardings = (p_sh, d_sh["caches"], d_sh["token"], d_sh["pos"])
            if cfg.is_encoder_decoder:
                args = args + (dspec["enc_kv"],)
                shardings = shardings + (d_sh["enc_kv"],)
            jitted = jax.jit(step, in_shardings=shardings,
                             out_shardings=(None, d_sh["caches"]), donate_argnums=(1,))
            lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return compiled, t_lower, t_compile


def _measure(compiled):
    """(flops, bytes, wire_bytes, collective_detail) of one compiled module.

    NOTE: XLA cost_analysis counts while-loop (scan) bodies ONCE, not
    times the trip count — which is exactly why lower_cell compiles two
    reduced-depth variants and extrapolates linearly in depth.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo = compiled.as_text()
    colls = rl.collective_bytes(hlo)
    wire = sum(v for k, v in colls.items() if k in rl._COLL_KINDS)
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        wire,
        colls,
    )


def lower_cell(arch: str, shape_name: str, multi_pod: bool, tcfg: Optional[TrainConfig] = None,
               cfg_override=None):
    """Compile one cell at full depth (the dry-run proof + memory analysis)
    and at depths 2g/4g for linear-in-depth cost extrapolation."""
    cfg = cfg_override or get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}, None
    tcfg = tcfg or TrainConfig()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = math.prod(mesh.devices.shape)

    # --- full-depth compile: the actual dry-run artifact ---
    compiled, t_lower, t_compile = _compile_one(cfg, shape, multi_pod, tcfg)
    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        }
    except Exception as e:  # backend-dependent
        mem_rec = {"error": str(e)}
    f_full, b_full, w_full, colls_full = _measure(compiled)

    # --- depth extrapolation: measure cost-exact (fully unrolled) variants
    # at 1 and 2 layer-groups, extrapolate linearly in group count ---
    g = cfg.layer_group
    l1, l2 = g, 2 * g
    from repro.utils.costmode import set_cost_exact

    try:
        set_cost_exact(True)  # fully unroll scans in the shallow variants
        c1, *_ = _compile_one(_depth_variant(cfg, l1), shape, multi_pod, tcfg)
        f1, b1, w1, colls1 = _measure(c1)
        del c1
        c2, *_ = _compile_one(_depth_variant(cfg, l2), shape, multi_pod, tcfg)
        f2, b2, w2, colls2 = _measure(c2)
        del c2
    finally:
        set_cost_exact(False)
    scale = (cfg.num_layers - l1) / (l2 - l1)
    flops = f1 + (f2 - f1) * scale
    bbytes = b1 + (b2 - b1) * scale
    wire = w1 + (w2 - w1) * scale
    colls_ext = {}
    for k in rl._COLL_KINDS:
        colls_ext[k] = colls1[k] + (colls2[k] - colls1[k]) * scale
    colls_ext["counts_full_module"] = colls_full["counts"]
    extrapolated = True

    mflops = rl.model_flops(cfg, shape)
    roof = rl.Roofline(
        flops_per_device=flops,
        bytes_per_device=bbytes,
        wire_bytes_per_device=wire,
        collective_detail=colls_ext,
        chips=chips,
        model_flops=mflops,
    )

    record = {
        "arch": arch,
        "shape": shape_name,
        "mode": shape.mode,
        "multi_pod": multi_pod,
        "chips": chips,
        "status": "ok",
        "mesh": list(mesh.devices.shape),
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "depth_extrapolated": extrapolated,
        "memory_analysis": mem_rec,
        "cost_analysis_module_raw": {"flops": f_full, "bytes accessed": b_full,
                                     "wire_bytes": w_full},
        "roofline": roof.to_dict(),
    }
    return record, compiled


def cell_filename(arch: str, shape_name: str, multi_pod: bool) -> str:
    pod = "2pod" if multi_pod else "1pod"
    return f"{arch.replace('/', '_')}__{shape_name}__{pod}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                fname = os.path.join(args.out, cell_filename(arch, shape_name, mp))
                if args.skip_existing and os.path.exists(fname):
                    print(f"[skip existing] {fname}")
                    continue
                print(f"=== {arch} x {shape_name} ({'2pod' if mp else '1pod'}) ===", flush=True)
                try:
                    record, compiled = lower_cell(arch, shape_name, mp)
                except Exception as e:
                    record = {"arch": arch, "shape": shape_name, "multi_pod": mp,
                              "status": "error", "error": f"{type(e).__name__}: {e}",
                              "traceback": traceback.format_exc()[-4000:]}
                    failures += 1
                with open(fname, "w") as f:
                    json.dump(record, f, indent=1)
                if record["status"] == "ok":
                    r = record["roofline"]
                    print(f"  compile {record['compile_s']}s | "
                          f"flops/dev {r['flops_per_device']:.3e} | "
                          f"bytes/dev {r['bytes_per_device']:.3e} | "
                          f"wire/dev {r['wire_bytes_per_device']:.3e} | "
                          f"bottleneck {r['bottleneck']} | t_step {r['t_step_s']*1e3:.2f} ms",
                          flush=True)
                elif record["status"] == "skipped":
                    print(f"  SKIPPED: {record['reason']}")
                else:
                    print(f"  ERROR: {record['error']}")
                compiled = None  # release
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
