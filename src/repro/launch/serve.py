"""Batched serving driver (CPU-example scale).

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --requests 6 --batch 2 --max-new 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving.engine import Request, ServingEngine


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    params = init_params(cfg, jax.random.key(0))
    engine = ServingEngine(cfg, params, batch=args.batch, max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for uid in range(args.requests):
        plen = int(rng.integers(4, 12))
        prompt = rng.integers(1, cfg.vocab_size, size=plen).astype(np.int32)
        engine.submit(Request(uid=uid, prompt=prompt, max_new_tokens=args.max_new,
                              temperature=args.temperature))
    engine.run_until_done()
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in engine.done.values())
    print(f"served {len(engine.done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
    for uid in sorted(engine.done):
        print(f"  req {uid}: {engine.done[uid].generated}")


if __name__ == "__main__":
    main()
