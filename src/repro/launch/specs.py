"""ShapeDtypeStruct input stand-ins + sharding specs for every
(arch x shape x mode) cell — the dry-run's contract with the model.

Nothing here allocates device memory.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig, AUDIO, VLM
from repro.models import abstract_caches
from repro.parallel.sharding import (
    MeshRules,
    param_pspec_tree,
    sanitize_spec,
    sanitized_sharding_tree,
)
from repro.train.steps import abstract_train_state


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mode: str) -> Dict[str, Any]:
    """Abstract batch for train/prefill."""
    b, s = shape.global_batch, shape.seq_len
    dt = cfg.dtype
    out: Dict[str, Any] = {}
    if cfg.family == VLM:
        p = cfg.num_prefix_tokens
        out["prefix_emb"] = sds((b, p, cfg.d_model), dt)
        out["tokens"] = sds((b, s - p), jnp.int32)
        if mode == "train":
            out["labels"] = sds((b, s - p), jnp.int32)
        return out
    if cfg.family == AUDIO:
        out["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), dt)
    out["tokens"] = sds((b, s), jnp.int32)
    if mode == "train":
        out["labels"] = sds((b, s), jnp.int32)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract (caches, token, pos[, enc_kv]) for one decode step with a
    cache of ``seq_len``."""
    b, s = shape.global_batch, shape.seq_len
    caches = abstract_caches(cfg, b, s)
    out = {
        "caches": caches,
        "token": sds((b, 1), jnp.int32),
        "pos": sds((b,), jnp.int32),
    }
    if cfg.is_encoder_decoder:
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        ek = sds((cfg.num_layers, b, cfg.encoder_seq, kv, hd), cfg.dtype)
        out["enc_kv"] = (ek, ek)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Public entry: abstract model inputs for the cell's mode."""
    if shape.mode in ("train", "prefill"):
        return batch_specs(cfg, shape, shape.mode)
    return decode_specs(cfg, shape)


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------

def batch_pspec(rules: MeshRules) -> P:
    return P(rules.batch)


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mode: str, mesh: Mesh, rules: MeshRules):
    bp = rules.batch
    specs = batch_specs(cfg, shape, mode)
    out: Dict[str, Any] = {}
    for name, leaf in specs.items():
        spec = P(*([bp] + [None] * (len(leaf.shape) - 1)))
        out[name] = NamedSharding(mesh, sanitize_spec(spec, leaf.shape, mesh))
    return out


def cache_pspec_tree(cfg: ModelConfig, caches, rules: MeshRules):
    """PartitionSpecs for decode caches.

    KV sequence dim is context-parallel over the model axis (rules.context);
    SSM/conv states shard d_inner over the model axis.
    """
    ctx = rules.context if rules.context else None
    bp = rules.batch

    def one(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        if name in ("k", "v"):
            return P(None, bp, ctx, None, None)
        if name == "conv":
            return P(None, bp, None, rules.tensor)
        if name == "ssm":
            return P(None, bp, rules.tensor, None)
        return P()

    return jax.tree_util.tree_map_with_path(one, caches)


def decode_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, rules: MeshRules, caches):
    dspec = decode_specs(cfg, shape)
    san = lambda spec, leaf: NamedSharding(mesh, sanitize_spec(spec, leaf.shape, mesh))
    out = {
        "caches": sanitized_sharding_tree(caches, cache_pspec_tree(cfg, caches, rules), mesh),
        "token": san(P(rules.batch, None), dspec["token"]),
        "pos": san(P(rules.batch), dspec["pos"]),
    }
    if cfg.is_encoder_decoder:
        ek = dspec["enc_kv"][0]
        ekv = san(P(None, rules.batch, None, None, None), ek)
        out["enc_kv"] = (ekv, ekv)
    return out


def _cfg_spec_overrides(cfg: ModelConfig, pspecs, rules: MeshRules):
    """Config-aware sharding-rule overrides (beyond the name-based rules).

    replicate_kv_proj: with MQA/GQA where kv_heads < tensor degree, a
    tensor-sharded wk/wv splits a single head across devices and XLA
    reshards K/V with collective-permutes every layer (measured: 34 GB/dev
    on paligemma prefill_32k).  Replicating the small output dim removes
    the storm at negligible flops cost.
    """
    if not cfg.replicate_kv_proj:
        return pspecs
    fsdp = rules.fsdp if rules.fsdp else None

    def fix(block):
        for w in ("wk", "wv"):
            if isinstance(block, dict) and w in block:
                nd = len(tuple(block[w]))
                block[w] = P(*([None] * (nd - 2) + [fsdp, None]))

    for scope in (pspecs.get("layers", {}),):
        for key in ("attn", "cross"):
            if key in scope:
                fix(scope[key])
    if "encoder" in pspecs and "attn" in pspecs["encoder"].get("layers", {}):
        fix(pspecs["encoder"]["layers"]["attn"])
    return pspecs


def state_shardings(cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh, rules: MeshRules):
    """Shardings for the full train state (params fp32 + opt m/v [+ err])."""
    state = abstract_train_state(cfg, tcfg)
    pspecs = _cfg_spec_overrides(cfg, param_pspec_tree(state["params"], rules), rules)
    tree_ns = lambda t: sanitized_sharding_tree(state["params"], t, mesh)
    shardings = {
        "step": NamedSharding(mesh, P()),
        "params": tree_ns(pspecs),
        "opt": {"m": tree_ns(pspecs), "v": tree_ns(pspecs)},
    }
    if "err" in state:
        shardings["err"] = tree_ns(pspecs)
    return state, shardings


def params_shardings(cfg: ModelConfig, mesh: Mesh, rules: MeshRules):
    from repro.models import abstract_params

    params = abstract_params(cfg)
    pspecs = _cfg_spec_overrides(cfg, param_pspec_tree(params, rules), rules)
    return params, sanitized_sharding_tree(params, pspecs, mesh)
