"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, in seconds (v5e):

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = wire_bytes_per_device / (LINKS_PER_CHIP * ICI_BW)

``cost_analysis()`` of the compiled (post-SPMD) executable gives
per-device FLOPs and bytes.  Collective bytes are not in cost_analysis:
we parse the optimized HLO text, sum result-shape bytes of every
collective op, and apply ring-cost multipliers (all-reduce 2x for its
reduce-scatter+all-gather decomposition; others 1x — the (n-1)/n ring
factor is ~1 at n >= 16 and is absorbed into the multiplier).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# --- TPU v5e constants (per chip) ---
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link
LINKS_PER_CHIP = 4  # 2D torus

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0}

# matches e.g. "bf16[16,128,2048]{2,1,0}" ; scalars "f32[]"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# an HLO instruction line: "  %name = <shape or tuple> <opcode>("
_OP_RE = re.compile(
    r"=\s*((?:\(?[\w\[\],{}\s/#*]*?\)?))\s*(" + "|".join(_COLL_KINDS) + r")(-start|-done)?\("
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum wire bytes (with multipliers) per collective kind.

    CPU-backend note: the SPMD partitioner *promotes* bf16 reductions to
    f32 (``to_apply=%add...clone_promoted`` + convert before/after); a
    real TPU reduces in bf16.  Promoted reduces are counted at half their
    printed bytes so the roofline reflects the TPU wire format.
    """
    out = {k: 0.0 for k in _COLL_KINDS}
    counts = {k: 0 for k in _COLL_KINDS}
    promoted_correction = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_txt, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue  # counted at -start
        nbytes = _shape_bytes(shape_txt) * _MULT[kind]
        if "clone_promoted" in line and "f32[" in shape_txt:
            promoted_correction += nbytes / 2
            nbytes /= 2
        out[kind] += nbytes
        counts[kind] += 1
    out["counts"] = counts  # type: ignore
    out["promoted_bf16_correction"] = promoted_correction  # type: ignore
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    collective_detail: Dict[str, float]
    chips: int
    model_flops: float  # 6*N*D (train) or 2*N_active*D (inference), global

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes_per_device / (LINKS_PER_CHIP * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_step(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO flops across all chips)."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Roofline MFU: model flops / (chips * peak * t_step)."""
        denom = self.chips * PEAK_FLOPS * self.t_step
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "collective_detail": self.collective_detail,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "t_step_s": self.t_step,
            "bottleneck": self.bottleneck,
            "useful_flops_fraction": self.useful_flops_fraction,
            "mfu_bound": self.mfu_bound,
        }


def model_flops(cfg, shape) -> float:
    """6*N*D for train, 2*N_active*D for inference (D = tokens processed)."""
    n_active = cfg.n_active_params()
    if shape.mode == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n_active * d
    if shape.mode == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n_active * d
    # decode: one token per row
    return 2.0 * n_active * shape.global_batch


def build(compiled_cost: Dict, hlo_text: str, chips: int, mflops: float) -> Roofline:
    colls = collective_bytes(hlo_text)
    wire = sum(v for k, v in colls.items() if k in _COLL_KINDS)
    return Roofline(
        flops_per_device=float(compiled_cost.get("flops", 0.0)),
        bytes_per_device=float(compiled_cost.get("bytes accessed", 0.0)),
        wire_bytes_per_device=wire,
        collective_detail=colls,
        chips=chips,
        model_flops=mflops,
    )
