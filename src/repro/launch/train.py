"""End-to-end training driver.

CPU-example scale by default (reduced config, tiny mesh or no mesh);
pass ``--production`` under a real TPU slice to use the full config and
the (data, model) production mesh.  Demonstrates the full production
loop: data pipeline -> jitted train step -> checkpoint cadence ->
failure recovery (supervisor) -> straggler monitoring.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 60 --batch 8 --seq 64 --reduced
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import TrainConfig, get_config
from repro.data.pipeline import PipelineConfig, SyntheticLMPipeline
from repro.runtime.fault_tolerance import StragglerMonitor, TrainLoopSupervisor
from repro.train.steps import init_train_state, make_train_step


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--no-reduced", dest="reduced", action="store_false")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--grad-compression", default="none", choices=["none", "topk", "int8"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failure-at", type=int, default=-1,
                    help="simulate a crash at this step (tests restart path)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tcfg = TrainConfig(
        learning_rate=args.lr,
        warmup_steps=10,
        total_steps=args.steps,
        microbatch=args.microbatch,
        grad_compression=args.grad_compression,
        checkpoint_every=args.ckpt_every,
        checkpoint_dir=args.ckpt_dir,
    )
    pipe = SyntheticLMPipeline(cfg, args.batch, args.seq, PipelineConfig(seed=tcfg.seed))
    mgr = CheckpointManager(tcfg.checkpoint_dir, keep=3)
    state = init_train_state(cfg, tcfg, jax.random.key(tcfg.seed))
    start_step = 0
    if args.resume and mgr.latest_step() is not None:
        state = mgr.restore(jax.eval_shape(lambda: state))
        start_step = int(state["step"])
        print(f"resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    straggler = StragglerMonitor()
    stateholder = {"state": state}
    inject = {"armed": args.inject_failure_at >= 0}

    def one_step(step: int) -> None:
        if inject["armed"] and step == args.inject_failure_at:
            inject["armed"] = False
            raise RuntimeError("injected failure (simulated node loss)")
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        t0 = time.time()
        stateholder["state"], metrics = step_fn(stateholder["state"], batch)
        dt = time.time() - t0
        if straggler.record(dt):
            print(f"[straggler] step {step} took {dt:.3f}s")
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} lr {float(metrics['lr']):.2e} "
                  f"({dt*1e3:.0f} ms)")

    def save(step: int) -> None:
        mgr.save(step, stateholder["state"], blocking=False)

    def restore() -> int:
        mgr.wait()
        latest = mgr.latest_step()
        if latest is None:
            stateholder["state"] = init_train_state(cfg, tcfg, jax.random.key(tcfg.seed))
            return 0
        stateholder["state"] = mgr.restore(jax.eval_shape(lambda: stateholder["state"]))
        print(f"[recovery] restored step {latest}")
        return latest

    sup = TrainLoopSupervisor(checkpoint_every=tcfg.checkpoint_every)
    final = sup.run(start_step, args.steps, one_step, save, restore)
    mgr.wait()
    mgr.save(final, stateholder["state"], blocking=True)
    print(f"done at step {final}; checkpoints in {tcfg.checkpoint_dir}")


if __name__ == "__main__":
    main()
