"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state).  Per spec: single pod = (data=16, model=16)
= 256 chips; multi-pod = (pod=2, data=16, model=16) = 512 chips.
"""

from __future__ import annotations

import math

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    from jax.sharding import Mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    if len(devs) > n:
        # dry-run environment exposes 512 host devices; the single-pod mesh
        # uses the first 256.
        return Mesh(np.array(devs[:n]).reshape(shape), axes)
    raise RuntimeError(
        f"mesh {shape} needs {n} devices, found {len(devs)} — "
        "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
        "(launch/dryrun.py sets this automatically)"
    )


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for multi-device CPU tests (8 fake devices)."""
    import jax
    from jax.sharding import Mesh

    devs = jax.devices()
    n = data * model
    if len(devs) < n:
        raise RuntimeError(f"need {n} devices, have {len(devs)}")
    return Mesh(np.array(devs[:n]).reshape(data, model), ("data", "model"))
