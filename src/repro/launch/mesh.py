"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state).  Per spec: single pod = (data=16, model=16)
= 256 chips; multi-pod = (pod=2, data=16, model=16) = 512 chips.
"""

from __future__ import annotations

import math

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    from jax.sharding import Mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    if len(devs) > n:
        # dry-run environment exposes 512 host devices; the single-pod mesh
        # uses the first 256.
        return Mesh(np.array(devs[:n]).reshape(shape), axes)
    raise RuntimeError(
        f"mesh {shape} needs {n} devices, found {len(devs)} — "
        "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
        "(launch/dryrun.py sets this automatically)"
    )


def make_flat_mesh(p: int | None = None, axis: str = "x"):
    """1-D mesh over the first ``p`` visible devices (all of them if None).

    The mesh the distributed Merge Path primitives
    (``repro.core.distributed_*``) expect: one named axis, contiguous
    shards.  Benchmarks and the multi-device tests use it with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to get a forced
    8-device host mesh; on real hardware it spans the ICI ring.
    """
    import jax
    from jax.sharding import Mesh

    devs = jax.devices()
    if p is None:
        p = len(devs)
    if len(devs) < p:
        raise RuntimeError(f"need {p} devices, have {len(devs)}")
    return Mesh(np.array(devs[:p]), (axis,))


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for multi-device CPU tests (8 fake devices)."""
    import jax
    from jax.sharding import Mesh

    devs = jax.devices()
    n = data * model
    if len(devs) < n:
        raise RuntimeError(f"need {n} devices, have {len(devs)}")
    return Mesh(np.array(devs[:n]).reshape(data, model), ("data", "model"))
