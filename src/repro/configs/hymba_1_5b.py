"""hymba-1.5b [hybrid] — parallel attn+mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Each layer runs attention and a mamba head in parallel on the same
normed input and sums the branches.  Most layers use sliding-window
attention (sub-quadratic => long_500k applies); one per group of 8 is
global, approximating Hymba's 3-full-attn-layer pattern within the
homogeneous-scan constraint (noted in DESIGN.md).
"""

from .base import ModelConfig, HYBRID

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family=HYBRID,
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    sliding_window=1024,
    global_every=8,
    subquadratic=True,
)
