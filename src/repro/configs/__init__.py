from .base import (
    ModelConfig,
    ShapeConfig,
    TrainConfig,
    SHAPES,
    SHAPES_BY_NAME,
    shape_applicable,
)
from .registry import REGISTRY, ALIASES, get_config, list_archs

__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "TrainConfig",
    "SHAPES",
    "SHAPES_BY_NAME",
    "shape_applicable",
    "REGISTRY",
    "ALIASES",
    "get_config",
    "list_archs",
]
