"""paligemma-3b [vlm] — SigLIP + gemma backbone [arXiv:2407.07726; hf].

18L d_model=2048 8H (GQA kv=1, MQA) d_ff=16384 vocab=257216.  The SigLIP
frontend is a STUB per spec: ``input_specs()`` provides 256 precomputed
patch embeddings which are linearly projected and prefixed (PrefixLM
mask: bidirectional over the prefix, causal over text).
"""

from .base import ModelConfig, VLM

CONFIG = ModelConfig(
    name="paligemma-3b",
    family=VLM,
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    num_prefix_tokens=256,
    act="gelu",
)
