"""falcon-mamba-7b [ssm] — mamba1 arch, attention-free [arXiv:2410.05355; unverified].

64L d_model=4096 (attn-free) vocab=65024, ssm_state=16, expand=2
(d_inner=8192), conv4.  O(1)-state decode => long_500k applies.
"""

from .base import ModelConfig, SSM

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family=SSM,
    num_layers=64,
    d_model=4096,
    num_heads=1,  # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    subquadratic=True,
)
