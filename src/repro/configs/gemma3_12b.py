"""gemma3-12b [dense] — 5:1 local:global, 128k context
[hf:google/gemma-3-1b-pt; unverified].

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.  Layers scan in
groups of 6 (5 sliding-window 1024 + 1 global) — the 5:1 interleave.
Sliding-window majority => long_500k decode is run (global layers hold
the full cache, context-parallel over the model axis).
"""

from .base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="gemma3-12b",
    family=DENSE,
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    sliding_window=1024,
    global_every=6,
    attn_logit_softcap=0.0,
    rope_theta=1_000_000.0,
    subquadratic=True,
)
