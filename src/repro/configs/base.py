"""Model / run configuration system.

``ModelConfig`` is a frozen dataclass describing one architecture from the
assigned pool; ``ShapeConfig`` describes one (seq_len, global_batch,
mode) input-shape cell.  ``reduced()`` returns a CPU-smoke-testable
shrink of the same family (same code paths, tiny dims).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# Families
DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
VLM = "vlm"
AUDIO = "audio"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    shared_expert_ff: int = 0  # dense shared-expert MLP width (0 = none)
    # "merge_path" (fused pure-JAX batched sort) | "merge_path_pallas"
    # (hierarchical tile engine, repro.kernels.ops) | "cumsum" (ablation)
    moe_dispatch: str = "merge_path"

    # --- SSM (mamba1) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128  # chunked-scan block (materialization/compile trade)
    # "scan" (chunked associative scan, pure XLA) | "fused" (Pallas VMEM
    # kernel, repro.kernels.ssm_scan — differentiable via chunk-recompute
    # custom_vjp, so it serves training as well as prefill)
    ssm_backend: str = "scan"

    # --- attention pattern ---
    sliding_window: int = 0  # 0 = all-global full attention
    global_every: int = 0  # gemma3: one global layer per `global_every`; 0 = all global
    attn_chunk: int = 1024  # kv-chunk for blockwise attention on long sequences
    attn_logit_softcap: float = 0.0

    # --- enc-dec / multimodal frontends (stubs provide embeddings) ---
    encoder_layers: int = 0
    encoder_seq: int = 0  # whisper: 1500 frames
    num_prefix_tokens: int = 0  # paligemma: 256 patch embeddings

    # --- misc ---
    act: str = "silu"  # silu (gated) | relu2 (nemotron) | gelu (whisper)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = True
    rms_eps: float = 1e-6
    dtype: str = "bfloat16"
    # nemotron-340B optimizer state exceeds one pod: shard FSDP over pod too
    fsdp_over_pod: bool = False
    remat: bool = True
    # --- beyond-paper perf knobs (§Perf hillclimb; defaults = baseline) ---
    train_attn_blockwise: bool = False  # online-softmax attention in training
    ssm_scan_dtype: str = "float32"  # associative-scan element dtype (bf16 halves bytes)
    remat_policy: str = "full"  # full | dots (save matmul outputs, recompute the rest)
    replicate_kv_proj: bool = False  # replicate wk/wv output dim (MQA/GQA with few kv heads)
    # long_500k applicability (sub-quadratic decode path exists)
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, math.ceil(self.d_model / 16))

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def layer_group(self) -> int:
        """Scan unit: layers are scanned in homogeneous groups."""
        return self.global_every if self.global_every > 0 else 1

    def n_params(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        per_layer = 0
        if self.family != SSM:
            per_layer += d * (self.num_heads + 2 * self.num_kv_heads) * hd
            per_layer += self.num_heads * hd * d
        if self.family in (SSM, HYBRID):
            di, st = self.d_inner, self.ssm_state
            per_layer += d * 2 * di + di * self.ssm_conv
            per_layer += di * (self.dt_rank + 2 * st) + self.dt_rank * di
            per_layer += di * st + di + di * d
        if self.num_experts:
            e, fe = self.num_experts, self.d_ff
            per_layer += d * e  # router
            per_layer += e * (3 * d * fe)
            if self.shared_expert_ff:
                per_layer += 3 * d * self.shared_expert_ff
        elif self.d_ff:
            mult = 3 if self.act in ("silu", "gelu_gated") else 2  # gated adds wg
            per_layer += mult * d * self.d_ff
        per_layer += 2 * d  # norms
        n += self.num_layers * per_layer
        if self.encoder_layers:
            enc = d * (self.num_heads + 2 * self.num_kv_heads) * hd + self.num_heads * hd * d
            enc += (3 if self.act in ("silu", "gelu_gated") else 2) * d * self.d_ff + 2 * d
            # + cross attention in decoder (already counted? add q/kv/o again)
            n += self.encoder_layers * enc
            n += self.num_layers * (d * (self.num_heads + 2 * self.num_kv_heads) * hd + self.num_heads * hd * d + d)
        n += d  # final norm
        return n

    def n_active_params(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if not self.num_experts:
            return self.n_params()
        d, fe = self.d_model, self.d_ff
        inactive = self.num_layers * (self.num_experts - self.experts_per_token) * 3 * d * fe
        return self.n_params() - inactive

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=max(2, self.layer_group),
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.num_experts else 0,
            shared_expert_ff=64 if self.shared_expert_ff else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            ssm_chunk=8,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            attn_chunk=32,
            encoder_layers=min(self.encoder_layers, 2) if self.encoder_layers else 0,
            encoder_seq=24 if self.encoder_seq else 0,
            num_prefix_tokens=8 if self.num_prefix_tokens else 0,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs; reason string when skipped."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500k decode is quadratic-cost/HBM-infeasible (per spec, skipped)"
    return True, ""


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Optimizer / runtime knobs (the run config half of the system)."""

    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    z_loss: float = 1e-4
    microbatch: int = 0  # 0 = no gradient accumulation
    # gradient compression for the cross-pod all-reduce
    grad_compression: str = "none"  # none | topk | int8
    compression_topk: float = 0.01
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    async_checkpoint: bool = True
    seed: int = 0
