"""moonshot-v1-16b-a3b [moe] — kimi/moonlight 64e top-6
[hf:moonshotai/Moonlight-16B-A3B; hf].

48L d_model=2048 16H (kv=16, MHA) d_ff=1408 (per expert) vocab=163840,
MoE 64 experts top-6 + shared expert.  MoE dispatch is the merge-path
stable kv-sort (the paper's technique as a first-class feature).
"""

from .base import ModelConfig, MOE

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family=MOE,
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    num_experts=64,
    experts_per_token=6,
    shared_expert_ff=2816,
    capacity_factor=1.25,
)
