"""whisper-large-v3 [audio] — enc-dec, conv frontend stub [arXiv:2212.04356; unverified].

32L decoder + 32L encoder, d_model=1280 20H (kv=20, MHA) d_ff=5120
vocab=51866.  The conv/mel frontend is a STUB per spec: ``input_specs()``
provides 1500 precomputed frame embeddings.  Decoder self-attn is causal
with cache; cross-attn reads the encoder output.  ``decode_*`` shapes
exercise the enc-dec cache path with synthetic long decoder contexts
(the real model caps at 448 decoder positions — noted); long_500k is
skipped (full attention).
"""

from .base import ModelConfig, AUDIO

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family=AUDIO,
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    encoder_layers=32,
    encoder_seq=1500,
    act="gelu",
    rope_theta=0.0,  # sinusoidal absolute positions
)
