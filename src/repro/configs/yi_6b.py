"""yi-6b [dense] — llama-arch GQA [arXiv:2403.04652; hf].

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""

from .base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="yi-6b",
    family=DENSE,
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    tie_embeddings=False,
)
