"""Architecture registry: ``--arch <id>`` resolution."""

from __future__ import annotations

from typing import Dict

from .base import ModelConfig

from . import (
    hymba_1_5b,
    moonshot_v1_16b_a3b,
    phi35_moe_42b_a6_6b,
    tinyllama_1_1b,
    yi_6b,
    gemma3_12b,
    nemotron_4_340b,
    falcon_mamba_7b,
    paligemma_3b,
    whisper_large_v3,
)

_MODULES = (
    hymba_1_5b,
    moonshot_v1_16b_a3b,
    phi35_moe_42b_a6_6b,
    tinyllama_1_1b,
    yi_6b,
    gemma3_12b,
    nemotron_4_340b,
    falcon_mamba_7b,
    paligemma_3b,
    whisper_large_v3,
)

REGISTRY: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}

ALIASES = {
    "hymba": "hymba-1.5b",
    "moonshot": "moonshot-v1-16b-a3b",
    "phi35-moe": "phi3.5-moe-42b-a6.6b",
    "phi3.5-moe": "phi3.5-moe-42b-a6.6b",
    "tinyllama": "tinyllama-1.1b",
    "yi": "yi-6b",
    "gemma3": "gemma3-12b",
    "nemotron": "nemotron-4-340b",
    "falcon-mamba": "falcon-mamba-7b",
    "paligemma": "paligemma-3b",
    "whisper": "whisper-large-v3",
}


def get_config(name: str) -> ModelConfig:
    key = ALIASES.get(name, name)
    if key not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[key]


def list_archs():
    return sorted(REGISTRY)
