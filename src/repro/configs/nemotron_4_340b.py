"""nemotron-4-340b [dense] — GQA, squared-ReLU [arXiv:2402.16819; unverified].

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.  relu^2 MLP
(no gating).  The only assigned config whose optimizer state exceeds a
single v5e pod's HBM => FSDP extends over the pod axis
(``fsdp_over_pod=True``), recorded in EXPERIMENTS.md.
"""

from .base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family=DENSE,
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    act="relu2",
    tie_embeddings=False,
    fsdp_over_pod=True,
)
