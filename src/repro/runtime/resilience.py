"""Guarded kernel dispatch: preflight checks, fallback chains, health counters.

Every public ``repro.kernels.ops`` entry point (and the distributed merge /
sort / top-k wrappers in ``repro.core.distributed``) routes through
:func:`guarded_call`.  For each call the guard walks an explicit attempt
chain — ``pallas-hier -> pallas-matrix -> core`` for the single-host
kernels, ``window -> gather`` for the distributed exchange — and returns
the first attempt that

1. passes **preflight**: runtime preconditions checked against the PR 7
   ``@kernel_contract`` registry (tile legality, the closed-form VMEM
   high-water model vs the A005 budget, length bounds);
2. **launches**: any exception out of the attempt (XLA launch failure,
   Pallas lowering error, injected :class:`~repro.runtime.faults.InjectedFault`)
   is caught here — and *only* here; lint rule L006 forbids swallowing
   kernel-launch failures anywhere else;
3. **verifies** (when verification is active): an op-specific output check
   (tok-space sortedness of the produced keys) rejects corrupted results.

Degradation is loud: each taken fallback edge emits a
:class:`FallbackWarning` and increments per-op health counters, surfaced by
``benchmarks/run.py`` so a silently-degraded CI run cannot report healthy
numbers.  When the whole chain is exhausted, :class:`GuardedDispatchError`
carries the per-attempt failure log.

Verification policy
-------------------
Output verification costs a host-side O(n) pass per call, which would blow
the CI perf anchors on the hot eager paths.  It is therefore **off by
default for the single-host kernels** and turns on automatically whenever a
fault plan is active (``repro.runtime.faults.active()``), or explicitly via
``REPRO_GUARD_VERIFY=1`` (``=0`` forces it off even under faults).  The
distributed wrappers verify by default — their perf anchor gates exchanged
bytes, not wall-clock.

Tracing bypass
--------------
The guard needs concrete operands: under ``jit`` / ``grad`` / ``vmap`` /
``eval_shape`` the inputs are tracers, Python control flow cannot branch on
device failures, and ``jax.custom_vjp`` traces its function.  When any
operand is a tracer (or ``REPRO_GUARD=0``) the wrapper dispatches the
primary attempt directly — the guard protects the eager call boundary, and
traced code is reached through an already-guarded eager entry point in the
serving and benchmark paths.

Environment knobs
-----------------
``REPRO_GUARD=0``         disable guarded dispatch (primary attempt only).
``REPRO_GUARD_VERIFY``    ``1`` always verify, ``0`` never; unset = only
                          while a fault plan is active.
``REPRO_GUARD_DEVICE``    key into ``VMEM_BUDGET_BYTES`` (e.g. ``tpu-v4``)
                          for the preflight budget; unset = the most
                          permissive budget, so preflight only rejects
                          configs that no supported device could run.
"""

from __future__ import annotations

import os
import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax

from repro.core import merge_path as _mp
from repro.runtime import faults as _faults
from repro.telemetry import get_telemetry

__all__ = [
    "FallbackWarning",
    "GuardedDispatchError",
    "OpHealth",
    "VerificationError",
    "guard_enabled",
    "guarded_call",
    "health",
    "health_summary",
    "is_tracing",
    "preflight",
    "reset_health",
    "sorted_kv_verifier",
    "sorted_verifier",
    "topk_verifier",
    "verify_active",
]


class FallbackWarning(UserWarning):
    """Emitted once per taken fallback edge (structured, never silent)."""


class GuardedDispatchError(RuntimeError):
    """Every attempt in a dispatch chain failed; carries the attempt log."""

    def __init__(self, op: str, log: List[str]):
        self.op = op
        self.log = list(log)
        super().__init__(f"guarded dispatch exhausted for {op!r}: " + "; ".join(log))


class VerificationError(RuntimeError):
    """An attempt produced output that failed its verifier."""


# ---------------------------------------------------------------------------
# policy knobs
# ---------------------------------------------------------------------------


def guard_enabled() -> bool:
    """Guarded dispatch is on unless ``REPRO_GUARD=0``."""
    return os.environ.get("REPRO_GUARD", "1") != "0"


def verify_active() -> bool:
    """Whether output verification runs for this call (see module docstring)."""
    raw = os.environ.get("REPRO_GUARD_VERIFY", "")
    if raw == "1":
        return True
    if raw == "0":
        return False
    return _faults.active()


def is_tracing(*values) -> bool:
    """True when any operand is a JAX tracer (guard must bypass)."""
    return any(isinstance(v, jax.core.Tracer) for v in values)


def _budget_bytes() -> int:
    from repro.analysis.checker import VMEM_BUDGET_BYTES, VMEM_USABLE_FRACTION

    device = os.environ.get("REPRO_GUARD_DEVICE", "")
    budget = VMEM_BUDGET_BYTES.get(device, max(VMEM_BUDGET_BYTES.values()))
    return int(budget * VMEM_USABLE_FRACTION)


# ---------------------------------------------------------------------------
# health counters
# ---------------------------------------------------------------------------


@dataclass
class OpHealth:
    """Mutable per-op counters (one instance per guarded op name)."""

    calls: int = 0
    fallbacks: int = 0
    precondition_rejects: int = 0
    launch_failures: int = 0
    verify_failures: int = 0
    faults_injected: int = 0
    exhausted: int = 0
    served_by: Dict[str, int] = field(default_factory=dict)
    fallback_edges: Dict[str, int] = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {
            "calls": self.calls,
            "fallbacks": self.fallbacks,
            "precondition_rejects": self.precondition_rejects,
            "launch_failures": self.launch_failures,
            "verify_failures": self.verify_failures,
            "faults_injected": self.faults_injected,
            "exhausted": self.exhausted,
            "served_by": dict(self.served_by),
            "fallback_edges": dict(self.fallback_edges),
        }


# Per-op health records live in the active telemetry registry
# (``get_telemetry().health``) so traces, bench summaries, and the
# ``python -m repro.telemetry`` CLI all see the same counters; these
# helpers keep the PR 8 call sites working unchanged.


def health(op: str) -> OpHealth:
    """The (auto-created) health record for ``op``."""
    store = get_telemetry().health
    rec = store.get(op)
    if rec is None:
        rec = store[op] = OpHealth()
    return rec


def reset_health() -> None:
    """Zero every per-op health record."""
    get_telemetry().health.clear()


def health_summary() -> dict:
    """``{op: counters}`` plus a ``"totals"`` roll-up across all ops."""
    store = get_telemetry().health
    totals = OpHealth()
    per_op = {}
    for op in sorted(store):
        rec = store[op]
        per_op[op] = rec.as_dict()
        totals.calls += rec.calls
        totals.fallbacks += rec.fallbacks
        totals.precondition_rejects += rec.precondition_rejects
        totals.launch_failures += rec.launch_failures
        totals.verify_failures += rec.verify_failures
        totals.faults_injected += rec.faults_injected
        totals.exhausted += rec.exhausted
    per_op["totals"] = totals.as_dict()
    return per_op


# ---------------------------------------------------------------------------
# preflight: runtime preconditions against the @kernel_contract registry
# ---------------------------------------------------------------------------

_MAX_N = 2**31 - 1  # cut tables and ranks are int32


def preflight(op: str, meta: Optional[dict], label: str, index: int) -> List[str]:
    """Reasons this attempt must not launch (empty list == go).

    ``meta`` carries the concrete call geometry (``n``, ``batch``,
    ``dtype``, ``tile``, ``leaf`` — or the scan geometry).  Checks:

    * length bounds: ``0 <= n <= int32 max`` (rank arithmetic is int32);
    * tile legality: ``tile >= 1``, ``1 <= leaf <= tile``, power-of-two
      tile when the contract demands it;
    * the A005 closed-form VMEM high-water model vs the device budget,
      for Pallas attempts only (``core`` twins never touch VMEM);
    * an injected ``vmem`` fault counts as a modeled breach.
    """
    if meta is None:
        return []
    reasons: List[str] = []
    n = meta.get("n")
    if n is not None and not (0 <= int(n) <= _MAX_N):
        reasons.append(f"n={n} outside [0, {_MAX_N}]")
    tile, leaf = meta.get("tile"), meta.get("leaf")
    if tile is not None:
        if int(tile) < 1:
            reasons.append(f"tile={tile} < 1")
        if leaf is not None and not (1 <= int(leaf) <= int(tile)):
            reasons.append(f"leaf={leaf} outside [1, tile={tile}]")
    is_pallas = label.startswith("pallas")
    if is_pallas and not reasons:
        from repro.analysis.checker import vmem_bytes
        from repro.analysis.lattice import LatticeConfig
        from repro.analysis.registry import REGISTRY

        contract = REGISTRY.get(op)
        if contract is not None:
            if contract.pow2_tile and tile is not None and (int(tile) & (int(tile) - 1)) != 0:
                reasons.append(f"tile={tile} not a power of two (contract {op})")
            engine = label.split("-", 1)[1] if "-" in label else meta.get("engine", "hier")
            cfg = LatticeConfig(
                dtype=meta.get("dtype", "float32"),
                n=int(meta.get("n", 4096) or 1),
                batch=int(meta.get("batch", 1) or 1),
                tile=int(tile or 512),
                leaf=int(leaf or 32),
                engine=engine,
                ragged=bool(meta.get("ragged", False)),
                seq=int(meta.get("seq", 256)),
                d_model=int(meta.get("d_model", 128)),
                state=int(meta.get("state", 8)),
                chunk=int(meta.get("chunk", 64)),
                d_tile=int(meta.get("d_tile", 64)),
            )
            try:
                need = vmem_bytes(contract, cfg)
            except Exception:  # model not defined for this geometry
                need = 0
            budget = _budget_bytes()
            if _faults.should_fire("vmem", op, index, label=label):
                health(op).faults_injected += 1
                reasons.append(f"injected vmem fault: modeled breach for {label}")
            elif need > budget:
                reasons.append(f"modeled VMEM {need}B exceeds budget {budget}B for {label}")
    return reasons


# ---------------------------------------------------------------------------
# output verifiers (tok-space order checks on host)
# ---------------------------------------------------------------------------


def _tok_np(x) -> np.ndarray:
    """Host copy of the IEEE-754 total-order keys for ``x`` (2-D)."""
    tok = np.asarray(_mp.total_order_keys(x))
    return tok[None, :] if tok.ndim == 1 else tok


def _rows_nondecreasing(tok: np.ndarray, lens, descending: bool = False) -> bool:
    # Elementwise comparisons, not diffs: an int64 difference between the
    # two key extremes wraps around and would flag correct output.
    if tok.shape[1] < 2:
        return True
    tok = tok.astype(np.int64)
    ok = tok[:, 1:] <= tok[:, :-1] if descending else tok[:, :-1] <= tok[:, 1:]
    if lens is None:
        return bool(np.all(ok))
    lens = np.asarray(lens, dtype=np.int64).reshape(-1)
    cols = np.arange(tok.shape[1] - 1, dtype=np.int64)[None, :]
    in_prefix = cols < (lens[:, None] - 1)
    return bool(np.all(ok | ~in_prefix))


def sorted_verifier(lens=None) -> Callable:
    """Verifier: output keys are nondecreasing in tok space.

    ``lens`` (per-row valid lengths) restricts the check to the valid
    prefix of each row — the padded tail of a ragged merge holds key
    sentinels that are checked by construction, and a NaN inside the valid
    prefix would otherwise sort *before* a float ``+inf`` pad and trip a
    full-row check on correct output.
    """

    def check(out) -> Optional[str]:
        keys = out[0] if isinstance(out, tuple) else out
        if not _rows_nondecreasing(_tok_np(keys), lens):
            return "output keys not nondecreasing in total-order space"
        return None

    return check


def sorted_kv_verifier(lens=None) -> Callable:
    """Alias of :func:`sorted_verifier` (tuple outputs verify keys)."""
    return sorted_verifier(lens)


def topk_verifier(descending: bool = True) -> Callable:
    """Verifier for ``(values, indices)`` top-k output.

    Checks the per-row *valid* slots (``indices >= 0``; masked ragged
    slots carry ``-1``) are nonincreasing in total-order space.  The check
    runs on ``tok(values)`` directly rather than through ``flip_desc``
    (negating a NaN is still a NaN): in tok space NaN is the *largest*
    key, so the NaN-first descending order produced by the core top-k on
    NaN-laced input verifies as correct.
    """

    def check(out) -> Optional[str]:
        vals, idx = out
        tok = _tok_np(vals)
        idx_np = np.asarray(idx)
        if idx_np.ndim == 1:
            idx_np = idx_np[None, :]
        lens = (idx_np >= 0).sum(axis=1)
        if not _rows_nondecreasing(tok, lens, descending=descending):
            return "top-k values not nonincreasing over valid slots"
        return None

    return check


# ---------------------------------------------------------------------------
# the dispatch loop
# ---------------------------------------------------------------------------


def guarded_call(
    op: str,
    attempts: Sequence[Tuple[str, Callable[[], object]]],
    *,
    index: Optional[int] = None,
    meta: Optional[dict] = None,
    verifier: Optional[Callable] = None,
    verify: Optional[bool] = None,
):
    """Walk the attempt chain for one call of ``op``; return the first good result.

    ``attempts`` is an ordered list of ``(label, thunk)``; the last entry
    is the oracle of record.  ``index`` is this call's position in the
    per-op stream (from ``faults.next_index``); when ``None`` it is taken
    here.  ``verify=None`` defers to the global policy
    (:func:`verify_active`); the distributed wrappers pass ``True``.
    """
    if index is None:
        index = _faults.next_index(op)
    rec = health(op)
    rec.calls += 1
    run_verify = verify_active() if verify is None else verify
    log: List[str] = []
    last_err: Optional[BaseException] = None
    n_att = len(attempts)
    attrs = {k: v for k, v in (meta or {}).items() if v is not None}
    with get_telemetry().span(f"op/{op}", index=index, **attrs) as sp:
        for i, (label, thunk) in enumerate(attempts):
            last = i == n_att - 1
            reasons = preflight(op, meta, label, index)
            if reasons:
                rec.precondition_rejects += 1
                log.append(f"{label}: preflight rejected ({'; '.join(reasons)})")
                continue
            if _faults.should_fire("launch", op, index, label=label, last=last):
                rec.faults_injected += 1
                rec.launch_failures += 1
                err = _faults.InjectedFault(f"injected launch failure: {op}[{index}] {label}")
                last_err = err
                log.append(f"{label}: {err}")
                continue
            try:
                out = thunk()
            except Exception as err:  # the one sanctioned launch-catch (L006)
                rec.launch_failures += 1
                last_err = err
                log.append(f"{label}: {type(err).__name__}: {err}")
                continue
            if _faults.should_fire("exchange", op, index, label=label, last=last):
                rec.faults_injected += 1
                out = _faults.corrupt(out, f"{op}:{index}:{label}")
            if run_verify and verifier is not None:
                problem = verifier(out)
                if problem is not None:
                    rec.verify_failures += 1
                    last_err = VerificationError(f"{op}[{index}] {label}: {problem}")
                    log.append(f"{label}: verify failed ({problem})")
                    continue
            rec.served_by[label] = rec.served_by.get(label, 0) + 1
            sp.set("served_by", label)
            if i > 0:
                rec.fallbacks += 1
                edge = f"{attempts[0][0]}->{label}"
                rec.fallback_edges[edge] = rec.fallback_edges.get(edge, 0) + 1
                sp.set("degraded", edge)
                warnings.warn(
                    f"guarded dispatch: {op}[{index}] degraded {edge} ({log[-1] if log else 'unknown'})",
                    FallbackWarning,
                    stacklevel=3,
                )
            return out
        rec.exhausted += 1
        sp.set("exhausted", True)
    raise GuardedDispatchError(op, log) from last_err
