"""Fault tolerance: heartbeat failure detection, straggler watch, elastic remesh.

On a real multi-host deployment these hook the coordination service
(heartbeats via the distributed KV store, SIGTERM-driven preemption
notices).  The logic itself is host-side and is unit-tested here with
simulated clocks/failures.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.telemetry import wall_seconds


@dataclasses.dataclass
class HeartbeatMonitor:
    """Declares a host dead after ``timeout`` seconds of silence."""

    num_hosts: int
    timeout: float = 60.0
    clock: Callable[[], float] = wall_seconds

    def __post_init__(self):
        now = self.clock()
        self.last_seen = {h: now for h in range(self.num_hosts)}

    def beat(self, host: int) -> None:
        self.last_seen[host] = self.clock()

    def dead_hosts(self) -> List[int]:
        now = self.clock()
        return [h for h, t in self.last_seen.items() if now - t > self.timeout]

    def healthy(self) -> bool:
        return not self.dead_hosts()


@dataclasses.dataclass
class StragglerMonitor:
    """Flags steps slower than ``factor`` x the rolling median.

    Mitigation at the framework level: a flagged straggler triggers (a)
    logging + metric export, and (b) after ``patience`` consecutive
    flags, an elastic remesh request that excludes the slow host (the
    same restart path as a failure, but planned).
    """

    window: int = 50
    factor: float = 2.0
    patience: int = 5

    def __post_init__(self):
        self.times: List[float] = []
        self.flags = 0

    def record(self, step_time: float) -> bool:
        med = sorted(self.times)[len(self.times) // 2] if self.times else step_time
        self.times.append(step_time)
        if len(self.times) > self.window:
            self.times.pop(0)
        slow = len(self.times) > 5 and step_time > self.factor * med
        self.flags = self.flags + 1 if slow else 0
        return slow

    def should_remesh(self) -> bool:
        return self.flags >= self.patience


def plan_elastic_mesh(
    available_chips: int,
    model_parallel: int,
    prefer_pods: bool = True,
    chips_per_pod: int = 256,
) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest usable (pod, data, model) mesh from surviving chips.

    Keeps the tensor-parallel degree fixed (param shardings stay valid)
    and shrinks the data/pod axes — restore then re-device_puts the
    checkpoint onto the new mesh (checkpoint.manager.restore).
    """
    if available_chips < model_parallel:
        raise ValueError(f"need >= {model_parallel} chips, have {available_chips}")
    pods = available_chips // chips_per_pod
    if prefer_pods and pods >= 2:
        data = chips_per_pod // model_parallel
        return (pods, data, model_parallel), ("pod", "data", "model")
    data = available_chips // model_parallel
    # largest power-of-two data degree keeps batch divisibility simple
    data = 1 << int(math.log2(data))
    return (data, model_parallel), ("data", "model")


@dataclasses.dataclass
class TrainLoopSupervisor:
    """Wraps the step loop: checkpoint cadence, failure injection hooks,
    restore-and-continue semantics.  Used by launch/train.py and the
    fault-tolerance tests."""

    checkpoint_every: int
    max_failures: int = 3

    def __post_init__(self):
        self.failures = 0

    def run(
        self,
        start_step: int,
        total_steps: int,
        step_fn: Callable[[int], None],
        save_fn: Callable[[int], None],
        restore_fn: Callable[[], int],
    ) -> int:
        """Runs steps with restart-on-exception; returns final step."""
        step = start_step
        while step < total_steps:
            try:
                step_fn(step)
                step += 1
                if step % self.checkpoint_every == 0:
                    save_fn(step)
            except Exception:
                self.failures += 1
                if self.failures > self.max_failures:
                    raise
                step = restore_fn()
        return step
