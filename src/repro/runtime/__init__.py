"""Runtime layer: self-healing execution on top of the static contracts.

Three pieces:

* :mod:`repro.runtime.resilience` — guarded kernel dispatch with explicit
  fallback chains, preflight contract checks, and per-op health counters;
* :mod:`repro.runtime.faults` — the deterministic fault injector that
  drives every fallback edge in tests and ``make test-faults``;
* :mod:`repro.runtime.fault_tolerance` — multi-host heartbeat / straggler
  / elastic-remesh logic for the training loop.
"""

from repro.runtime.fault_tolerance import (
    HeartbeatMonitor,
    StragglerMonitor,
    TrainLoopSupervisor,
    plan_elastic_mesh,
)
from repro.runtime.faults import (
    FaultSpec,
    InjectedFault,
    fired_events,
    inject,
    nan_lace,
    parse_plan,
    reset_counters,
)
from repro.runtime.resilience import (
    FallbackWarning,
    GuardedDispatchError,
    OpHealth,
    VerificationError,
    guard_enabled,
    guarded_call,
    health_summary,
    preflight,
    reset_health,
    verify_active,
)

__all__ = [
    "FallbackWarning",
    "FaultSpec",
    "GuardedDispatchError",
    "HeartbeatMonitor",
    "InjectedFault",
    "OpHealth",
    "StragglerMonitor",
    "TrainLoopSupervisor",
    "VerificationError",
    "fired_events",
    "guard_enabled",
    "guarded_call",
    "health_summary",
    "inject",
    "nan_lace",
    "parse_plan",
    "plan_elastic_mesh",
    "preflight",
    "reset_counters",
    "reset_health",
    "verify_active",
]
