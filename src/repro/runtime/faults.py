"""Deterministic fault injection for the guarded dispatch layer.

The resilience layer (``repro.runtime.resilience``) degrades along explicit
fallback chains (``pallas-hier -> pallas-matrix -> core``, distributed
``window -> gather``).  Those edges are worthless untested, and real faults
(an XLA launch failure, a VMEM overflow, a flipped bit in a collective
exchange, a NaN key from upstream) are rare and nondeterministic.  This
module makes every failure class *reproducible*:

* a **fault plan** selects (fault class, op, call indices, attempt label);
* plans come from the ``REPRO_FAULTS`` environment variable or from the
  stackable :func:`inject` context manager (tests use the latter, the
  ``make test-faults`` CI target uses the former);
* all pseudo-randomness (NaN lacing positions) is seeded from
  ``zlib.crc32`` of a caller-supplied salt — **never** from wall-clock or
  from Python's process-salted ``hash()`` — so a failing run replays
  exactly.

Fault classes
-------------
``launch``
    The selected dispatch attempt raises :class:`InjectedFault` instead of
    running, forcing the guard onto the next edge of the chain.
``vmem``
    The preflight VMEM model is treated as over budget for the selected
    Pallas attempt (a modeled breach — no kernel is launched).
``exchange``
    The selected attempt's *result* is corrupted (min/max value swap) after
    it runs, so output verification must catch it and degrade.
``nan``
    Float key operands are laced with NaNs before dispatch, exercising the
    total-order fallback semantics end to end.

Plan grammar
------------
``REPRO_FAULTS`` (and :func:`inject`) take ``;``-separated specs::

    cls:op:indices[:match]

* ``cls``     — one of ``launch | vmem | exchange | nan``;
* ``op``      — guarded op name (``merge``, ``sort_batched``,
  ``distributed_merge``, ``serving.decode``, ...) or ``*`` for all;
* ``indices`` — comma-separated 0-based per-op call indices, or ``*``;
* ``match``   — optional substring filter on the attempt label
  (``pallas-hier``, ``window``, ...); when omitted the fault applies to
  any attempt *except the final one* of a chain, so a wildcard plan
  degrades every call to its oracle instead of bricking it.

Example: ``launch:merge:0,2;nan:sort*:*`` fails the Pallas launch on merge
calls 0 and 2 and NaN-laces the keys of every ``sort*`` call.
"""

from __future__ import annotations

import contextlib
import fnmatch
import os
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "FaultSpec",
    "InjectedFault",
    "active",
    "corrupt",
    "fired_events",
    "inject",
    "nan_lace",
    "next_index",
    "parse_plan",
    "reset_counters",
    "should_fire",
]

FAULT_CLASSES = ("launch", "vmem", "exchange", "nan")


class InjectedFault(RuntimeError):
    """Raised by an attempt selected for a ``launch`` fault."""


@dataclass(frozen=True)
class FaultSpec:
    """One parsed ``cls:op:indices[:match]`` clause of a fault plan."""

    cls: str
    op: str = "*"
    indices: Optional[Tuple[int, ...]] = None  # None == every call
    match: str = ""  # substring filter on the attempt label; "" == default

    def selects(self, cls: str, op: str, index: int) -> bool:
        if cls != self.cls:
            return False
        if not fnmatch.fnmatchcase(op, self.op):
            return False
        return self.indices is None or index in self.indices


@dataclass(frozen=True)
class FaultEvent:
    """Audit record of one fault that actually fired."""

    cls: str
    op: str
    index: int
    label: str


# ---------------------------------------------------------------------------
# plan state: env plan (cached on the raw env value) + an inject() stack
# ---------------------------------------------------------------------------

_ENV_VAR = "REPRO_FAULTS"
_STACK: List[Tuple[FaultSpec, ...]] = []
_ENV_CACHE: Tuple[str, Tuple[FaultSpec, ...]] = ("", ())
_COUNTERS: Dict[str, int] = {}
_FIRED: List[FaultEvent] = []


def parse_plan(plan: str) -> Tuple[FaultSpec, ...]:
    """Parse a ``;``-separated plan string into :class:`FaultSpec` tuples."""
    specs = []
    for clause in plan.split(";"):
        clause = clause.strip()
        if not clause:
            continue
        parts = clause.split(":")
        if len(parts) < 2 or len(parts) > 4:
            raise ValueError(f"bad fault clause {clause!r} (want cls:op[:indices[:match]])")
        cls, op = parts[0].strip(), parts[1].strip()
        if cls not in FAULT_CLASSES:
            raise ValueError(f"unknown fault class {cls!r} (want one of {FAULT_CLASSES})")
        raw_idx = parts[2].strip() if len(parts) > 2 else "*"
        indices: Optional[Tuple[int, ...]]
        if raw_idx in ("", "*"):
            indices = None
        else:
            indices = tuple(int(tok) for tok in raw_idx.split(",") if tok.strip())
        match = parts[3].strip() if len(parts) > 3 else ""
        specs.append(FaultSpec(cls=cls, op=op or "*", indices=indices, match=match))
    return tuple(specs)


def _env_specs() -> Tuple[FaultSpec, ...]:
    global _ENV_CACHE
    raw = os.environ.get(_ENV_VAR, "")
    if raw != _ENV_CACHE[0]:
        _ENV_CACHE = (raw, parse_plan(raw))
    return _ENV_CACHE[1]


def _specs() -> Tuple[FaultSpec, ...]:
    specs = _env_specs()
    for layer in _STACK:
        specs = specs + layer
    return specs


def active() -> bool:
    """True when any fault plan (env or :func:`inject`) is in force."""
    return bool(_specs())


@contextlib.contextmanager
def inject(plan: str):
    """Context manager activating ``plan`` (stacks on top of ``REPRO_FAULTS``).

    Per-op call counters and the fired-event log are snapshotted on entry
    and restored on exit, so each ``with inject(...)`` block sees call
    index 0 for every op and leaves no trace behind.
    """
    specs = parse_plan(plan)
    saved_counters = dict(_COUNTERS)
    saved_fired = list(_FIRED)
    _COUNTERS.clear()
    _FIRED.clear()
    _STACK.append(specs)
    try:
        yield
    finally:
        _STACK.pop()
        _COUNTERS.clear()
        _COUNTERS.update(saved_counters)
        _FIRED[:] = saved_fired


def next_index(op: str) -> int:
    """Return this call's 0-based index for ``op`` and advance the counter.

    Called exactly once per guarded call (not per attempt), so a plan's
    ``indices`` address stable positions in the call stream regardless of
    how many fallback attempts each call burns.
    """
    idx = _COUNTERS.get(op, 0)
    _COUNTERS[op] = idx + 1
    return idx


def reset_counters() -> None:
    """Zero every per-op call counter and clear the fired-event log."""
    _COUNTERS.clear()
    _FIRED.clear()


def should_fire(cls: str, op: str, index: int, label: str = "", last: bool = False) -> bool:
    """Pure query: does the active plan fire ``cls`` on this attempt?

    ``label`` is the dispatch attempt label; a spec with an explicit
    ``match`` fires only when ``match`` is a substring of ``label``.  A
    spec *without* a match never fires on the final attempt of a chain
    (``last=True``), so wildcard plans always leave the oracle edge alive.
    Fires are recorded in :func:`fired_events`.
    """
    for spec in _specs():
        if not spec.selects(cls, op, index):
            continue
        if spec.match:
            if spec.match not in label:
                continue
        elif last:
            continue
        _FIRED.append(FaultEvent(cls=cls, op=op, index=index, label=label))
        return True
    return False


def fired_events() -> List[FaultEvent]:
    """Copy of every fault that fired since the last reset/inject entry."""
    return list(_FIRED)


# ---------------------------------------------------------------------------
# deterministic payload mutators
# ---------------------------------------------------------------------------


def _rng(salt: str) -> np.random.Generator:
    # crc32 (not hash()): stable across processes and interpreter runs
    return np.random.default_rng(zlib.crc32(salt.encode("utf-8")))


def nan_lace(x, salt: str):
    """Return ``x`` with ~1/8 of its elements (>=1) replaced by NaN.

    Positions are drawn from a crc32(salt)-seeded generator, so a test can
    reproduce the exact laced operand independently (same salt -> same
    lacing).  Non-float inputs are returned unchanged.
    """
    arr = np.asarray(x)
    if not np.issubdtype(arr.dtype, np.floating) or arr.size == 0:
        return x
    flat = arr.astype(arr.dtype, copy=True).reshape(-1)
    count = max(1, flat.size // 8)
    pos = _rng(salt).choice(flat.size, size=count, replace=False)
    flat[pos] = np.nan
    out = flat.reshape(arr.shape)
    import jax.numpy as jnp

    return jnp.asarray(out) if not isinstance(x, np.ndarray) else out


def corrupt(x, salt: str = ""):
    """Deterministically corrupt an attempt result (exchange fault).

    Swaps the values at the first-min and first-max positions of the
    flattened array (keys only, for ``(keys, values)`` tuples).  On any
    non-constant array this is guaranteed to break sortedness — after the
    swap the first element of the flattened view holds the global max while
    a strictly smaller element follows it — so output verification must
    reject the attempt.  Constant arrays are returned unchanged (there is
    no order to violate).  ``salt`` is accepted for signature stability;
    the mutation itself is position-deterministic and needs no randomness.
    """
    if isinstance(x, tuple):
        return (corrupt(x[0], salt),) + tuple(x[1:])
    arr = np.asarray(x)
    if arr.size < 2:
        return x
    flat = arr.copy().reshape(-1)
    if np.issubdtype(flat.dtype, np.floating):
        if not np.any(~np.isnan(flat)):
            return x
        i, j = int(np.nanargmin(flat)), int(np.nanargmax(flat))
    else:
        i, j = int(np.argmin(flat)), int(np.argmax(flat))
    if flat[i] == flat[j]:
        return x
    flat[i], flat[j] = flat[j], flat[i]
    out = flat.reshape(arr.shape)
    import jax.numpy as jnp

    return jnp.asarray(out) if not isinstance(x, np.ndarray) else out


def maybe_nan_lace(op: str, index: int, args: tuple, key_positions: Sequence[int]) -> tuple:
    """Lace the key operands of a guarded call when a ``nan`` fault selects it.

    ``key_positions`` are the indices into ``args`` holding key arrays
    (values are never laced — NaN payloads do not affect comparisons).
    Salts are ``"{op}:{index}:{pos}"`` so tests can rebuild the exact laced
    operands with :func:`nan_lace` and compare against an oracle.
    """
    if not key_positions or not should_fire("nan", op, index):
        return args
    out = list(args)
    for pos in key_positions:
        out[pos] = nan_lace(out[pos], f"{op}:{index}:{pos}")
    return tuple(out)
