"""Distributed Merge Path across 8 (simulated) devices.

Must be launched fresh (jax locks device count at first init):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_sort_demo.py
"""

import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import distributed_merge, distributed_sort, distributed_topk
from repro.core.distributed import exchange_bytes


def main():
    print(f"devices: {len(jax.devices())}")
    rng = np.random.default_rng(0)

    # merge two sharded sorted arrays: each device computes exactly its
    # 1/P slice of the output (Corollary 7, over ICI instead of a cache).
    # The default exchange="window" moves each element once (O(N/P) per
    # device); exchange="gather" is the bit-identical all-gather oracle.
    a = np.sort(rng.standard_normal(1 << 14)).astype(np.float32)
    b = np.sort(rng.standard_normal(1 << 14)).astype(np.float32)
    out = np.asarray(distributed_merge(jnp.array(a), jnp.array(b)))
    assert (np.diff(out) >= 0).all()
    oracle = np.asarray(distributed_merge(jnp.array(a), jnp.array(b), exchange="gather"))
    assert np.array_equal(out, oracle)
    eb = exchange_bytes(len(a), len(b), len(jax.devices()), 4)
    print(
        f"distributed_merge of 2x{len(a)}: sorted ok, window==gather; "
        f"bytes/device {eb['window_payload']} (window) vs {eb['gather']} (gather)"
    )

    # sample sort: local merge-path sorts -> splitters -> ONE all_to_all
    # bucket round -> single multiway co-rank combine of the ragged runs
    x = rng.standard_normal(1 << 15).astype(np.float32)
    s, cnt, ovf = distributed_sort(jnp.array(x))
    assert not bool(np.asarray(ovf))
    print(f"distributed_sort of {len(x)}: ok, bucket counts {np.asarray(cnt).tolist()}")

    # distributed top-k: butterfly combine (k*log2 P candidates per device)
    v, i = distributed_topk(jnp.array(x), 8)
    rv, _ = jax.lax.top_k(jnp.array(x), 8)
    assert np.allclose(np.asarray(v), np.asarray(rv))
    print(f"distributed_topk: {np.asarray(v)[:4]} ...")
    print("demo OK")


if __name__ == "__main__":
    main()
