"""End-to-end driver: train a reduced LM for a few hundred steps on CPU,
with checkpointing and a simulated mid-run failure + recovery.

    PYTHONPATH=src python examples/train_lm.py [--arch moonshot-v1-16b-a3b]

For a ~100M-parameter run (closer to the deliverable's "train ~100M
model" scale; several hours on this single-core CPU container, real on
any accelerator) pass ``--preset 100m``.
"""

import argparse

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    args = ap.parse_args()

    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--ckpt-dir", "/tmp/repro_example_ckpt",
            "--inject-failure-at", str(args.steps // 2),
            "--ckpt-every", "50"]
    if args.preset == "100m":
        argv += ["--batch", "8", "--seq", "512", "--no-reduced"]
    else:
        argv += ["--batch", "8", "--seq", "128"]
    train_mod.main(argv)


if __name__ == "__main__":
    main()
