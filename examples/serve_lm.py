"""Serve a small model with batched requests (continuous batching engine,
merge-path top-k sampling).

    PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch import serve as serve_mod


def main():
    serve_mod.main(["--arch", "tinyllama-1.1b", "--requests", "8",
                    "--batch", "4", "--max-new", "12", "--temperature", "0.8"])


if __name__ == "__main__":
    main()
