"""Quickstart: the Merge Path core in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    diagonal_intersections,
    merge,
    merge_sort,
    partitioned_merge,
    stable_argsort,
    topk_desc,
)
from repro.kernels.merge_path import merge_pallas


def main():
    rng = np.random.default_rng(0)
    a = jnp.asarray(np.sort(rng.integers(0, 100, 12)).astype(np.int32))
    b = jnp.asarray(np.sort(rng.integers(0, 100, 12)).astype(np.int32))
    print("A =", a)
    print("B =", b)

    # 1. The merge path partition: cut the (virtual) path at equispaced
    #    cross diagonals — each segment is an independent merge job.
    p = 4
    diags = jnp.arange(p, dtype=jnp.int32) * (24 // p)
    ai = diagonal_intersections(a, b, diags)
    print(f"partition at diagonals {list(map(int, diags))}: "
          f"a_starts={list(map(int, ai))} b_starts={list(map(int, diags - ai))}")

    # 2. Merge three ways: flat rank-merge, the paper's p-core algorithm,
    #    and the Pallas SPM kernel (interpret mode on CPU).
    out_flat = merge(a, b)
    out_part = partitioned_merge(a, b, p)
    out_pallas = merge_pallas(a, b, tile=8)
    assert (out_flat == out_part).all() and (out_flat == out_pallas).all()
    print("merged:", out_flat)

    # 3. Merge sort + stable argsort + top-k built on the same partition math.
    x = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
    assert (merge_sort(x) == jnp.sort(x)).all()
    keys = jnp.asarray(rng.integers(0, 5, 10).astype(np.int32))
    print("stable argsort of", keys, "->", stable_argsort(keys))
    v, i = topk_desc(x, 5)
    print("top-5:", v)
    print("quickstart OK")


if __name__ == "__main__":
    main()
