#!/usr/bin/env python
"""Engine 2 of the static checker: repo-specific AST lint over ``src/``.

Every rule here encodes a bug class that already cost a PR to find and
fix (the catalog with full rationale lives in ``docs/analysis.md``):

* **L001** — no literal ``interpret=True`` / ``interpret=False`` at call
  sites.  The interpret default must route through
  ``ops.DEFAULT_INTERPRET`` (the ``REPRO_PALLAS_INTERPRET`` env switch),
  otherwise a hard-coded call site silently pins interpret mode on a
  real TPU — or compiled mode on the CPU CI box.
* **L002** — no ``-x`` negation of keys to get descending order.  For
  int keys ``-x`` overflows at ``iinfo.min`` and collapses ties'
  stability; the sanctioned form is ``repro.core.merge_path.flip_desc``
  (bit-flip ``~x``), exact at every representable value.
* **L003** — no raw ``iinfo`` / ``finfo`` / ``.inf`` sentinel
  construction outside the one sanctioned helper module
  (``src/repro/core/merge_path.py``: ``max_sentinel`` / ``min_sentinel``
  / ``flip_desc``).  Scattered sentinel spellings are how the
  pad-vs-real-key collision bug slipped in.
* **L004** — no Python ``for`` loop in ``kernels/`` that launches a
  Pallas kernel per iteration (loop-over-pairs).  One launch per round
  with the pairing folded into the grid is the whole point of the flat
  round kernel; a Python loop re-introduces O(rounds * pairs) dispatch.
* **L005** — every ``custom_vjp`` forward must be paired with a
  registered gradient test: the outermost enclosing function's name
  (underscores stripped) must appear in some ``tests/*.py`` that
  exercises gradients.  An untested backward is how silent wrong
  gradients ship.
* **L006** — no bare ``except:`` / ``except Exception:`` around a kernel
  launch outside the guard layer
  (``src/repro/runtime/resilience.py``).  Swallowing a launch failure
  anywhere else bypasses the fallback chain, the health counters, and
  the ``FallbackWarning`` — exactly the silent degradation the guarded
  dispatch exists to prevent.
* **L007** — no raw ``time.perf_counter()`` / ``time.monotonic()``
  outside the telemetry clock layer (``src/repro/telemetry/``) and the
  shared bench timer (``benchmarks/_timing.py``).  The serving engine's
  traces replay bit-identically *because* every timestamp routes
  through the pluggable telemetry clock; a stray wall-clock read is how
  nondeterminism leaks back in.  Use ``repro.telemetry.wall_seconds``
  (or ``WALL`` / a ``Telemetry`` span) instead.

Suppression: append ``# lint: ok`` (any rule) or ``# lint: ok(L004)``
(one rule) to the flagged line.  Stdlib ``ast`` only — the container is
offline, so no third-party linters.
"""

from __future__ import annotations

import ast
import re
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

REPO_ROOT = Path(__file__).resolve().parent.parent

# the one module allowed to spell sentinels from iinfo/finfo/inf
SANCTIONED_SENTINEL_FILES = ("src/repro/core/merge_path.py",)

# the one module allowed to catch launch failures broadly (guarded dispatch)
SANCTIONED_LAUNCH_CATCH_FILES = ("src/repro/runtime/resilience.py",)

# the places allowed to read the raw wall clock (L007): the telemetry
# clock layer itself and the shared benchmark timer
SANCTIONED_WALL_CLOCK_DIRS = ("src/repro/telemetry/",)
SANCTIONED_WALL_CLOCK_FILES = ("benchmarks/_timing.py",)

# raw-clock callables L007 forbids elsewhere
_WALL_CLOCK_NAMES = ("perf_counter", "monotonic")

# callables whose arguments are "keys" for L002's descending-order check
_KEYED_CALL = re.compile(r"(sort|topk|top_k|merge|argsort)", re.IGNORECASE)
# kernel-launching callees for L004
_LAUNCH_CALL = re.compile(r"(_pallas$|^pallas_call$)")

_SUPPRESS = re.compile(r"#\s*lint:\s*ok(?:\(([A-Z0-9, ]+)\))?")


@dataclass(frozen=True)
class LintViolation:
    rule: str  # "L001".."L005"
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _suppressions(source: str) -> Dict[int, Optional[Set[str]]]:
    """line -> None (suppress all rules) or a set of rule ids."""
    out: Dict[int, Optional[Set[str]]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS.search(line)
        if m:
            rules = m.group(1)
            out[i] = None if rules is None else {
                r.strip() for r in rules.split(",") if r.strip()
            }
    return out


def _suppressed(sup: Dict[int, Optional[Set[str]]], line: int, rule: str) -> bool:
    if line not in sup:
        return False
    rules = sup[line]
    return rules is None or rule in rules


def _callee_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _is_custom_vjp_expr(node: ast.AST) -> bool:
    """``jax.custom_vjp`` / ``custom_vjp`` as a bare decorator, or
    ``functools.partial(jax.custom_vjp, ...)``."""
    if isinstance(node, ast.Attribute) and node.attr == "custom_vjp":
        return True
    if isinstance(node, ast.Name) and node.id == "custom_vjp":
        return True
    if isinstance(node, ast.Call):
        if _callee_name(node) == "custom_vjp":
            return True
        if _callee_name(node) == "partial" and node.args:
            return _is_custom_vjp_expr(node.args[0])
    return False


def _negated_key_args(call: ast.Call):
    """Yield ``-x`` arguments (non-literal unary minus) of a keyed call."""
    for arg in call.args:
        if (
            isinstance(arg, ast.UnaryOp)
            and isinstance(arg.op, ast.USub)
            and not isinstance(arg.operand, ast.Constant)
            # -x.inf spellings are L003's business, not a key negation
            and not (isinstance(arg.operand, ast.Attribute) and arg.operand.attr == "inf")
        ):
            yield arg


def lint_source(
    source: str,
    path: str,
    *,
    collect_vjp_owners: Optional[List[str]] = None,
) -> List[LintViolation]:
    """Lint one file's source.  ``path`` is repo-relative (used for the
    per-file rule scopes).  If ``collect_vjp_owners`` is given, the
    outermost function name owning each ``custom_vjp`` is appended to it
    for the cross-file L005 check."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [LintViolation("L000", path, e.lineno or 0, f"syntax error: {e.msg}")]
    sup = _suppressions(source)
    posix = Path(path).as_posix()
    in_kernels = "/kernels/" in posix or posix.startswith("kernels/")
    sanctioned = any(posix.endswith(s) for s in SANCTIONED_SENTINEL_FILES)
    launch_catch_ok = any(posix.endswith(s) for s in SANCTIONED_LAUNCH_CATCH_FILES)
    wall_clock_ok = any(d in posix for d in SANCTIONED_WALL_CLOCK_DIRS) or any(
        posix.endswith(s) for s in SANCTIONED_WALL_CLOCK_FILES
    )
    vs: List[LintViolation] = []

    # ancestry map so custom_vjp sites resolve to their outermost function
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node

    def _outermost_function(node: ast.AST) -> Optional[str]:
        owner = None
        cur: Optional[ast.AST] = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                owner = cur.name
            cur = parents.get(cur)
        return owner

    for node in ast.walk(tree):
        # --- L001: literal interpret= at call sites -----------------------
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if (
                    kw.arg == "interpret"
                    and isinstance(kw.value, ast.Constant)
                    and isinstance(kw.value.value, bool)
                ):
                    line = kw.value.lineno
                    if not _suppressed(sup, line, "L001"):
                        vs.append(LintViolation(
                            "L001", path, line,
                            f"literal interpret={kw.value.value} at a call "
                            f"site — route through ops.DEFAULT_INTERPRET "
                            f"(REPRO_PALLAS_INTERPRET env) instead"))

        # --- L002: -x key negation for descending order -------------------
        if isinstance(node, ast.Call) and _KEYED_CALL.search(_callee_name(node)):
            for arg in _negated_key_args(node):
                if not _suppressed(sup, arg.lineno, "L002"):
                    vs.append(LintViolation(
                        "L002", path, arg.lineno,
                        f"unary minus on a key argument of "
                        f"{_callee_name(node)}() — int keys overflow at "
                        f"iinfo.min; use repro.core.merge_path.flip_desc"))

        # --- L003: raw sentinel construction outside the helper -----------
        if not sanctioned:
            if isinstance(node, ast.Call) and _callee_name(node) in ("iinfo", "finfo"):
                if not _suppressed(sup, node.lineno, "L003"):
                    vs.append(LintViolation(
                        "L003", path, node.lineno,
                        f"raw {_callee_name(node)}() sentinel construction — "
                        f"use repro.core.merge_path.max_sentinel / "
                        f"min_sentinel"))
            if (
                isinstance(node, ast.Attribute)
                and node.attr == "inf"
                and isinstance(node.value, ast.Name)
                and node.value.id in ("np", "jnp", "numpy", "math")
            ):
                if not _suppressed(sup, node.lineno, "L003"):
                    vs.append(LintViolation(
                        "L003", path, node.lineno,
                        f"raw {node.value.id}.inf sentinel — use "
                        f"repro.core.merge_path.max_sentinel / min_sentinel"))

        # --- L004: per-iteration kernel launches in kernels/ --------------
        if in_kernels and isinstance(node, ast.For):
            if not _suppressed(sup, node.lineno, "L004"):
                for inner in ast.walk(node):
                    if isinstance(inner, ast.Call) and _LAUNCH_CALL.search(
                        _callee_name(inner)
                    ):
                        vs.append(LintViolation(
                            "L004", path, node.lineno,
                            f"Python for-loop launching "
                            f"{_callee_name(inner)}() per iteration — fold "
                            f"the pairing into the kernel grid (one launch "
                            f"per round)"))
                        break

        # --- L006: broad except around a kernel launch outside the guard --
        if not launch_catch_ok and isinstance(node, ast.Try):
            launches = any(
                isinstance(inner, ast.Call) and _LAUNCH_CALL.search(_callee_name(inner))
                for stmt in node.body
                for inner in ast.walk(stmt)
            )
            if launches:
                for handler in node.handlers:
                    broad = handler.type is None or (
                        isinstance(handler.type, (ast.Name, ast.Attribute))
                        and (
                            handler.type.id
                            if isinstance(handler.type, ast.Name)
                            else handler.type.attr
                        )
                        in ("Exception", "BaseException")
                    )
                    if broad and not _suppressed(sup, handler.lineno, "L006"):
                        vs.append(LintViolation(
                            "L006", path, handler.lineno,
                            "broad except around a kernel launch — only the "
                            "guard layer (repro.runtime.resilience."
                            "guarded_call) may catch launch failures; route "
                            "the call through guarded dispatch instead"))

        # --- L007: raw wall-clock reads outside the telemetry layer -------
        if not wall_clock_ok:
            hit_name = None
            if (
                isinstance(node, ast.Attribute)
                and node.attr in _WALL_CLOCK_NAMES
                and isinstance(node.value, ast.Name)
                and node.value.id == "time"
            ):
                hit_name = f"time.{node.attr}"
            elif isinstance(node, ast.ImportFrom) and node.module == "time":
                for alias in node.names:
                    if alias.name in _WALL_CLOCK_NAMES:
                        hit_name = f"from time import {alias.name}"
                        break
            if hit_name is not None and not _suppressed(sup, node.lineno, "L007"):
                vs.append(LintViolation(
                    "L007", path, node.lineno,
                    f"raw {hit_name} outside src/repro/telemetry/ and "
                    f"benchmarks/_timing.py — wall-clock reads break the "
                    f"deterministic-tick trace guarantee; use "
                    f"repro.telemetry.wall_seconds (or a telemetry span)"))

        # --- L005 collection: custom_vjp owners ---------------------------
        if collect_vjp_owners is not None:
            hit = None
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if any(_is_custom_vjp_expr(d) for d in node.decorator_list):
                    hit = node
            if hit is not None:
                owner = _outermost_function(hit) or hit.name
                collect_vjp_owners.append(owner)

    return vs


def _grad_test_corpus(repo_root: Path) -> str:
    """Concatenated text of every tests/*.py that exercises gradients."""
    chunks = []
    tests = repo_root / "tests"
    if tests.is_dir():
        for p in sorted(tests.glob("*.py")):
            text = p.read_text()
            if "grad" in text:
                chunks.append(text)
    return "\n".join(chunks)


def vjp_pairing_violations(
    owners: Sequence[Tuple[str, str, int]], grad_corpus: str
) -> List[LintViolation]:
    """L005: each (owner, path, line) must appear word-boundary in the
    gradient test corpus, with leading underscores stripped (private
    forwards are tested through their public wrapper's name)."""
    vs = []
    for owner, path, line in owners:
        public = owner.lstrip("_")
        if not re.search(rf"\b{re.escape(public)}\b", grad_corpus):
            vs.append(LintViolation(
                "L005", path, line,
                f"custom_vjp forward {owner!r} has no registered gradient "
                f"test (no tests/*.py mentioning 'grad' references "
                f"{public!r})"))
    return vs


def _lint_paths(root: Path) -> List[Path]:
    """Files lint_tree covers: ``src/**`` and ``benchmarks/**`` (the bench
    timers are inside the L007 wall-clock perimeter)."""
    paths = sorted((root / "src").rglob("*.py"))
    bench = root / "benchmarks"
    if bench.is_dir():
        paths += sorted(bench.rglob("*.py"))
    return paths


def lint_tree(repo_root: Optional[Path] = None) -> List[LintViolation]:
    """Lint ``src/**/*.py`` + ``benchmarks/**/*.py`` plus the cross-file
    L005 pairing."""
    root = Path(repo_root) if repo_root else REPO_ROOT
    vs: List[LintViolation] = []
    owners: List[Tuple[str, str, int]] = []
    for p in _lint_paths(root):
        rel = p.relative_to(root).as_posix()
        per_file: List[str] = []
        vs += lint_source(p.read_text(), rel, collect_vjp_owners=per_file)
        # re-walk for line numbers of the collected owners
        if per_file:
            tree = ast.parse(p.read_text(), filename=rel)
            lines = {}
            for node in ast.walk(tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    lines.setdefault(node.name, node.lineno)
            for owner in per_file:
                owners.append((owner, rel, lines.get(owner, 0)))
    vs += vjp_pairing_violations(owners, _grad_test_corpus(root))
    return vs


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=str(REPO_ROOT), help="repo root to lint")
    args = ap.parse_args(argv)
    vs = lint_tree(Path(args.root))
    if vs:
        for v in vs:
            print(f"lint: {v}", file=sys.stderr)
        print(f"lint: FAIL ({len(vs)} violations)", file=sys.stderr)
        return 1
    print("lint: OK (AST rules L001-L007 clean)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
