"""Repo tooling (docs coverage gate, AST lint, bench-diff perf gate).

A package so in-repo scripts (``benchmarks/run.py``) can import the
anchor-row definitions from ``tools.bench_diff`` instead of duplicating
them; every module here also runs standalone (``python tools/<x>.py``).
"""
