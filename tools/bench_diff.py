#!/usr/bin/env python
"""Perf-regression gate over the ``BENCH_<n>.json`` trajectory.

``make ci`` records one smoke-benchmark snapshot per PR (the 0.2 -> ~12
Melem/s trajectory in the repo root).  This tool closes the ROADMAP's
"perf-regression gate" item:

* ``--check`` compares the two most recent snapshots' **anchor rows**
  and fails (exit 1) on a >20% regression in any of them:

  - ``merge_throughput/pallas_spm_tile512`` — the headline single-merge
    throughput (time anchor);
  - ``batched_merge/batched_pallas_2d_grid`` — the batched 2-D grid
    anchor (time anchor);
  - ``distributed/merge_window`` — compared on the **deterministic**
    ``bytes/device`` count parsed from the derived column, because the
    row's wall-clock includes multi-process startup noise.

  Missing baseline (fewer than two snapshots, or an anchor row absent
  from either side) is handled gracefully: report and exit 0 — the gate
  must not brick the first run.

* ``--next`` prints the snapshot name the *current* ``make ci`` run
  should write: highest existing ``BENCH_<n>.json`` + 1.  The Makefile
  derives ``BENCH_JSON`` from this, so PRs can't forget the bump.

Non-anchor rows are intentionally ignored: smoke-mode timings of the
small paper tables are too noisy to gate on, while the anchors run big
enough problems to be stable between runs.
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple

REPO_ROOT = Path(__file__).resolve().parent.parent
THRESHOLD = 0.20  # fail on >20% regression in an anchor row

# (name substring, metric): "time" gates on us_per_call going UP,
# "bytes" on the derived bytes/device count going UP
ANCHORS: Tuple[Tuple[str, str], ...] = (
    ("merge_throughput/pallas_spm_tile512", "time"),
    ("batched_merge/batched_pallas_2d_grid", "time"),
    ("distributed/merge_window", "bytes"),
)

_BYTES = re.compile(r"bytes/device=(\d+)")
_SNAP = re.compile(r"^BENCH_(\d+)\.json$")


def snapshots(root: Optional[Path] = None) -> List[Tuple[int, Path]]:
    """Existing ``(n, path)`` snapshots, ascending by n."""
    root = REPO_ROOT if root is None else Path(root)
    out = []
    for p in root.glob("BENCH_*.json"):
        m = _SNAP.match(p.name)
        if m:
            out.append((int(m.group(1)), p))
    return sorted(out)


def next_name(root: Optional[Path] = None) -> str:
    """Snapshot name the current CI run should write (highest + 1)."""
    snaps = snapshots(root)
    return f"BENCH_{snaps[-1][0] + 1 if snaps else 1}.json"


def _telemetry_bytes(payload: dict) -> Optional[float]:
    """Per-device window-payload bytes from the snapshot's telemetry block
    (recorded analytically by ``core.distributed``)."""
    gauges = (payload.get("telemetry") or {}).get("gauges") or {}
    gauge = gauges.get("distributed.exchange_bytes.window_payload") or {}
    last = gauge.get("last")
    return float(last) if last is not None else None


def anchor_values(payload: dict) -> Dict[str, Tuple[str, float]]:
    """Anchor rows of one snapshot: row name -> (metric, value)."""
    tel_bytes = _telemetry_bytes(payload)
    out: Dict[str, Tuple[str, float]] = {}
    for row in payload.get("rows", []):
        name = row.get("name", "")
        for pat, metric in ANCHORS:
            if pat in name:
                if metric == "bytes":
                    # preferred source: the telemetry gauge; the derived-row
                    # regex remains as fallback for pre-telemetry snapshots
                    if tel_bytes is not None:
                        out[name] = ("bytes", tel_bytes)
                    else:
                        m = _BYTES.search(str(row.get("derived", "")))
                        if m:
                            out[name] = ("bytes", float(m.group(1)))
                else:
                    out[name] = ("time", float(row["us_per_call"]))
                break
    return out


def diff(
    base: dict, current: dict, threshold: float = THRESHOLD
) -> Tuple[List[str], List[str]]:
    """Compare anchor rows; return (regressions, notes)."""
    regressions, notes = [], []
    if bool(base.get("smoke")) != bool(current.get("smoke")):
        notes.append("smoke flags differ between snapshots — skipping diff")
        return regressions, notes
    b, c = anchor_values(base), anchor_values(current)
    for name in sorted(set(b) | set(c)):
        if name not in b or name not in c:
            side = "baseline" if name not in b else "current"
            notes.append(f"anchor {name!r} missing from the {side} snapshot — skipped")
            continue
        metric, bv = b[name]
        _, cv = c[name]
        if bv <= 0:
            notes.append(f"anchor {name!r} has non-positive baseline — skipped")
            continue
        ratio = cv / bv - 1.0
        unit = "us/call" if metric == "time" else "bytes/device"
        if ratio > threshold:
            regressions.append(
                f"{name}: {bv:.0f} -> {cv:.0f} {unit} "
                f"(+{ratio:.0%} > {threshold:.0%} threshold)"
            )
        else:
            notes.append(f"{name}: {bv:.0f} -> {cv:.0f} {unit} ({ratio:+.0%}) OK")
    if not (set(b) & set(c)):
        notes.append("no anchor rows common to both snapshots")
    return regressions, notes


def check(root: Optional[Path] = None, threshold: float = THRESHOLD) -> int:
    snaps = snapshots(root)
    if len(snaps) < 2:
        print(f"bench-diff: {len(snaps)} snapshot(s) found — no baseline yet, OK")
        return 0
    (bn, bp), (cn, cp) = snaps[-2], snaps[-1]
    base = json.loads(bp.read_text())
    current = json.loads(cp.read_text())
    regressions, notes = diff(base, current, threshold)
    for note in notes:
        print(f"bench-diff: {note}")
    if regressions:
        for r in regressions:
            print(f"bench-diff: REGRESSION {bp.name} -> {cp.name}: {r}",
                  file=sys.stderr)
        return 1
    print(f"bench-diff: OK ({bp.name} -> {cp.name})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--next", action="store_true",
                    help="print the BENCH_<n>.json name the current run should write")
    ap.add_argument("--check", action="store_true",
                    help="diff the two most recent snapshots' anchor rows")
    ap.add_argument("--root", default=str(REPO_ROOT),
                    help="directory holding the BENCH_*.json snapshots")
    ap.add_argument("--threshold", type=float, default=THRESHOLD,
                    help="fractional regression that fails the gate")
    args = ap.parse_args(argv)
    root = Path(args.root)
    if args.next:
        print(next_name(root))
        return 0
    return check(root, args.threshold)


if __name__ == "__main__":
    raise SystemExit(main())
