"""Fail if any public API symbol is missing from docs/architecture.md.

Public surface checked:

* every name in ``repro.core.__all__`` (the library's primary boundary);
* every public function defined in ``repro.kernels.ops`` (the kernel
  dispatch surface), plus its documented module-level switches;
* every name in ``repro.analysis.__all__`` (the static checker's surface);
* every name in ``repro.runtime.__all__`` (the self-healing execution
  layer: guarded dispatch, fault injection, fault tolerance) plus the
  serving degradation surface (``Request`` / ``ServingReport``);
* every name in ``repro.telemetry.__all__`` (spans, metrics, trace
  export).

Wired to ``make docs-check`` (and ``make ci``), so a PR that adds a public
symbol without documenting it fails CI.  Symbols may be documented in
``docs/architecture.md``, ``docs/robustness.md``, or
``docs/observability.md`` (the pages are searched as one corpus).  The check requires each symbol as a whole word
(word-boundary regex, so ``merge`` is not satisfied by
``merge_batched``) — the "Public API index" section lists every symbol
by name.
"""

from __future__ import annotations

import inspect
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

DOCS = (
    os.path.join(ROOT, "docs", "architecture.md"),
    os.path.join(ROOT, "docs", "robustness.md"),
    os.path.join(ROOT, "docs", "observability.md"),
)


def public_symbols() -> dict:
    """Map of ``module -> sorted public symbol names`` to require."""
    import repro.analysis as analysis
    import repro.core as core
    import repro.kernels.ops as ops
    import repro.runtime as runtime
    import repro.telemetry as telemetry

    ops_names = sorted(
        name
        for name, obj in vars(ops).items()
        if not name.startswith("_")
        and inspect.isfunction(obj)
        and obj.__module__ == "repro.kernels.ops"
    )
    ops_names.append("DEFAULT_INTERPRET")  # the documented env-driven switch
    return {
        "repro.core": sorted(core.__all__),
        "repro.kernels.ops": ops_names,
        "repro.analysis": sorted(analysis.__all__),
        "repro.runtime": sorted(runtime.__all__),
        "repro.serving.engine": ["Request", "ServingReport", "ServingEngine"],
        "repro.telemetry": sorted(telemetry.__all__),
    }


def main() -> int:
    missing_docs = [d for d in DOCS if not os.path.exists(d)]
    if missing_docs:
        print(f"docs-check: FAIL — missing doc page(s): {', '.join(missing_docs)}")
        return 1
    text = "\n".join(open(d).read() for d in DOCS)
    missing = []
    for module, names in public_symbols().items():
        for name in names:
            if not re.search(rf"\b{re.escape(name)}\b", text):
                missing.append(f"{module}.{name}")
    if missing:
        print("docs-check: FAIL — public symbols missing from docs/ "
              "(architecture.md + robustness.md + observability.md):")
        for m in missing:
            print(f"  - {m}")
        return 1
    total = sum(len(v) for v in public_symbols().values())
    print(f"docs-check: OK ({total} public symbols documented)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
