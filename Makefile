# CI / local tooling for the Merge Path reproduction.
# All targets wrap the tier-1 command with PYTHONPATH=src (see ROADMAP.md).

PY ?= python

# perf-trajectory point written by `make ci`: derived automatically as
# highest existing BENCH_<n>.json + 1, so PRs can't forget the bump
BENCH_JSON ?= $(shell $(PY) tools/bench_diff.py --next)

.PHONY: test test-faults bench-smoke bench lint check ci docs-check train-smoke trace-smoke

# tier-1 verify (ROADMAP.md)
test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# fault-injection sweep: the fuzz tests in tests/test_faults.py run every
# guarded fallback edge, then the same suite re-runs under an env-driven
# plan (REPRO_FAULTS) so the degraded paths are exercised end to end the
# way production would hit them
test-faults:
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_faults.py
	PYTHONPATH=src REPRO_FAULTS="launch:merge:0;launch:sort:0;exchange:distributed_merge:0:window" \
		$(PY) -m pytest -x -q tests/test_faults.py -k env_plan

# docs coverage gate: every public repro.core / repro.kernels.ops /
# repro.runtime symbol must appear in docs/architecture.md or
# docs/robustness.md
docs-check:
	PYTHONPATH=src $(PY) tools/docs_check.py

# one real train step on the kernel path (fused SSM scan + Pallas MoE
# dispatch): finite loss, nonzero grad on every param leaf, params move
train-smoke:
	PYTHONPATH=src $(PY) -m repro.train.smoke

# telemetry gate: run a small serving workload + one distributed merge,
# write the Perfetto trace, and assert it is healthy — zero unclosed
# spans and Cor. 7 window balance ratio <= 1.05
trace-smoke:
	PYTHONPATH=src $(PY) -m repro.telemetry.smoke --out trace.json
	PYTHONPATH=src $(PY) -m repro.telemetry --check trace.json

# static analysis, run before anything launches: abstract kernel-contract
# checker (eval_shape only — zero device kernels), repo-specific AST lint,
# and the perf-regression gate over existing BENCH_*.json anchor rows
check:
	PYTHONPATH=src $(PY) -m repro.analysis
	$(PY) tools/lint_rules.py
	$(PY) tools/bench_diff.py --check

# full CI: static analysis first (contract violations fail fast, no
# kernels run), then tier-1 tests + fault-injection sweep + docs gate +
# kernel-path train step + smoke benchmarks recording the perf point
# (benchmarks/run.py fails if any fallback fired on the clean tree), then
# the bench-diff gate re-checks the fresh snapshot against the previous PR's
ci: check test test-faults docs-check train-smoke trace-smoke
	PYTHONPATH=src $(PY) benchmarks/run.py --smoke --json $(BENCH_JSON)
	$(PY) tools/bench_diff.py --check

# fast benchmark sweep (<60 s): small sizes of every paper benchmark
bench-smoke:
	PYTHONPATH=src $(PY) benchmarks/run.py --smoke

# full benchmark sweep (minutes)
bench:
	PYTHONPATH=src $(PY) benchmarks/run.py

# bytecode-compile everything (syntax/indentation/encoding errors) plus
# the repo-specific AST rules (stdlib ast only — the container is offline)
lint:
	$(PY) -m compileall -q src tests benchmarks examples tools
	$(PY) tools/lint_rules.py
	@echo "lint OK"
