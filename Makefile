# CI / local tooling for the Merge Path reproduction.
# All targets wrap the tier-1 command with PYTHONPATH=src (see ROADMAP.md).

PY ?= python

.PHONY: test bench-smoke bench lint

# tier-1 verify (ROADMAP.md)
test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# fast benchmark sweep (<60 s): small sizes of every paper benchmark
bench-smoke:
	PYTHONPATH=src $(PY) benchmarks/run.py --smoke

# full benchmark sweep (minutes)
bench:
	PYTHONPATH=src $(PY) benchmarks/run.py

# no third-party linters are baked into the container, so lint =
# bytecode-compile everything (catches syntax/indentation/encoding errors)
lint:
	$(PY) -m compileall -q src tests benchmarks examples
	@echo "lint OK"
