# CI / local tooling for the Merge Path reproduction.
# All targets wrap the tier-1 command with PYTHONPATH=src (see ROADMAP.md).

PY ?= python

# perf-trajectory point written by `make ci` (bump per PR: BENCH_2, BENCH_3, ...)
BENCH_JSON ?= BENCH_6.json

.PHONY: test bench-smoke bench lint ci docs-check train-smoke

# tier-1 verify (ROADMAP.md)
test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# docs coverage gate: every public repro.core / repro.kernels.ops symbol
# must appear in docs/architecture.md
docs-check:
	PYTHONPATH=src $(PY) tools/docs_check.py

# one real train step on the kernel path (fused SSM scan + Pallas MoE
# dispatch): finite loss, nonzero grad on every param leaf, params move
train-smoke:
	PYTHONPATH=src $(PY) -m repro.train.smoke

# full CI: tier-1 tests + docs gate + kernel-path train step + smoke
# benchmarks, recording the perf point that future PRs regress against
# (batched anchor, tile engine, distributed gather-vs-window bytes)
ci: test docs-check train-smoke
	PYTHONPATH=src $(PY) benchmarks/run.py --smoke --json $(BENCH_JSON)

# fast benchmark sweep (<60 s): small sizes of every paper benchmark
bench-smoke:
	PYTHONPATH=src $(PY) benchmarks/run.py --smoke

# full benchmark sweep (minutes)
bench:
	PYTHONPATH=src $(PY) benchmarks/run.py

# no third-party linters are baked into the container, so lint =
# bytecode-compile everything (catches syntax/indentation/encoding errors)
lint:
	$(PY) -m compileall -q src tests benchmarks examples
	@echo "lint OK"
