"""MoE dispatch invariants, samplers, serving engine end-to-end."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import init_params
from repro.models.moe import _positions_cumsum, _positions_merge_path, capacity, moe_apply
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampler import greedy, topk_sample, topp_sample


# --- MoE dispatch ------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 7), min_size=1, max_size=200))
def test_merge_path_positions_match_cumsum(assignments):
    """The merge-path dispatch computes exactly the one-hot-cumsum
    position-in-expert (the O(N*E) baseline)."""
    flat = jnp.array(assignments, jnp.int32)
    pos_mp = np.asarray(_positions_merge_path(flat, 8))
    pos_cs = np.asarray(_positions_cumsum(flat, 8))
    np.testing.assert_array_equal(pos_mp, pos_cs)


def test_moe_conservation_no_drops():
    """With no capacity pressure, expert outputs combine to all tokens:
    output must be finite and routing weights sum to 1."""
    cfg = dataclasses.replace(get_config("phi3.5-moe-42b-a6.6b").reduced(),
                              capacity_factor=8.0)
    params = init_params(cfg, jax.random.key(0))
    layer0 = jax.tree.map(lambda t: t[0], params["layers"])
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y = moe_apply(layer0["moe"], x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()


def test_moe_drop_determinism():
    """Capacity drops are deterministic and position-ordered (stability of
    the merge-path sort): two identical calls give identical outputs."""
    cfg = dataclasses.replace(get_config("moonshot-v1-16b-a3b").reduced(),
                              capacity_factor=0.5)
    params = init_params(cfg, jax.random.key(0))
    layer0 = jax.tree.map(lambda t: t[0], params["layers"])
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    y1 = moe_apply(layer0["moe"], x, cfg)
    y2 = moe_apply(layer0["moe"], x, cfg)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_moe_dispatch_modes_agree():
    """merge_path and cumsum dispatch produce identical layer outputs."""
    base = get_config("phi3.5-moe-42b-a6.6b").reduced()
    x = jax.random.normal(jax.random.key(1), (2, 24, base.d_model))
    outs = {}
    for mode in ("merge_path", "cumsum"):
        cfg = dataclasses.replace(base, moe_dispatch=mode)
        params = init_params(cfg, jax.random.key(0))
        layer0 = jax.tree.map(lambda t: t[0], params["layers"])
        outs[mode] = np.asarray(moe_apply(layer0["moe"], x, cfg))
    np.testing.assert_allclose(outs["merge_path"], outs["cumsum"], rtol=1e-5, atol=1e-5)


def test_capacity_lane_aligned():
    cfg = get_config("moonshot-v1-16b-a3b")
    c = capacity(cfg, 4096)
    assert c % 8 == 0
    assert c >= 4096 * cfg.experts_per_token / cfg.num_experts


# --- samplers ----------------------------------------------------------------

def test_greedy_matches_argmax():
    logits = jax.random.normal(jax.random.key(0), (4, 100))
    np.testing.assert_array_equal(np.asarray(greedy(logits)),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_topk_sample_support():
    """Samples only come from the top-k set."""
    logits = jnp.asarray(np.random.default_rng(0).standard_normal((3, 64)), jnp.float32)
    topk_sets = [set(np.asarray(jax.lax.top_k(logits[i], 5)[1]).tolist()) for i in range(3)]
    for seed in range(20):
        s = topk_sample(logits, jax.random.key(seed), k=5, temperature=1.0)
        for i in range(3):
            assert int(s[i]) in topk_sets[i]


def test_topp_always_keeps_best():
    logits = jnp.asarray([[10.0] + [0.0] * 63], jnp.float32)
    for seed in range(5):
        s = topp_sample(logits, jax.random.key(seed), p=0.01, k_max=8)
        assert int(s[0]) == 0


# --- serving engine ----------------------------------------------------------

def test_serving_engine_end_to_end():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_params(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, batch=2, max_seq=48)
    rng = np.random.default_rng(0)
    for uid in range(5):
        eng.submit(Request(uid=uid, prompt=rng.integers(1, cfg.vocab_size, 6).astype(np.int32),
                           max_new_tokens=4, temperature=0.0))
    eng.run_until_done()
    assert len(eng.done) == 5
    for r in eng.done.values():
        assert len(r.generated) == 4
        assert all(0 <= t < cfg.vocab_size for t in r.generated)


def test_serving_greedy_matches_manual_decode():
    """Engine greedy output == manual prefill+decode loop."""
    from repro.models import forward_prefill, forward_decode

    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_params(cfg, jax.random.key(0))
    prompt = np.asarray([3, 14, 15, 9], np.int32)

    eng = ServingEngine(cfg, params, batch=1, max_seq=32)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=3, temperature=0.0))
    eng.run_until_done()
    got = eng.done[0].generated

    last, caches, _ = forward_prefill(
        cfg, jax.tree.map(lambda p: p, params), {"tokens": jnp.asarray(prompt)[None]},
        cache_len=32,
    )
    toks = []
    cur = int(jnp.argmax(last[0]))
    toks.append(cur)
    pos = len(prompt)
    for _ in range(2):
        logits, caches = forward_decode(
            cfg, params, caches, jnp.asarray([[cur]], jnp.int32),
            jnp.asarray([pos], jnp.int32),
        )
        cur = int(jnp.argmax(logits[0]))
        toks.append(cur)
        pos += 1
    assert got == toks, (got, toks)
