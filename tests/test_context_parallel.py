"""Context-parallel decode attention: shard_map combine == dense reference."""

import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_context_parallel_matches_dense():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp, math, functools
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.core.distributed import shard_map  # jax-version compat wrapper
        from repro.serving.context_parallel import context_parallel_decode_attention

        B, S, K, G, hd = 2, 64, 2, 3, 16
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.standard_normal((B, K, G, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, K, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, K, hd)), jnp.float32)
        valid = jnp.asarray(np.arange(S)[None, :] <= 40).repeat(B, 0)

        mesh = Mesh(np.array(jax.devices()).reshape(8), ("ctx",))
        fn = shard_map(
            functools.partial(context_parallel_decode_attention, axis_name="ctx"),
            mesh=mesh,
            in_specs=(P(), P(None, "ctx"), P(None, "ctx"), P(None, "ctx")),
            out_specs=P(),
            check_vma=False,
        )
        out = fn(q, k, v, valid)

        # dense reference
        s = jnp.einsum("bkgh,bskh->bkgs", q, k) / math.sqrt(hd)
        s = jnp.where(valid[:, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        ref = jnp.einsum("bkgs,bskh->bkgh", p, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
        print("ok")
    """)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, f"{proc.stdout}\n{proc.stderr}"
