"""Fault-injection sweep: drive every guarded fallback edge deterministically.

Each fault class (``launch``, ``vmem``, ``exchange``, ``nan``) is forced
through :mod:`repro.runtime.faults` and the degraded output is asserted
bit-identical to the clean chain or a NumPy/total-order oracle — the
self-healing layer's contract is that a fallback changes *where* the
answer is computed, never the answer.

Also covers the serving engine's graceful degradation (deadlines,
load-shedding, bounded retry, partial-result surfacing) and the
multi-device distributed chains (subprocess, 8 fake CPU devices).

Pure pytest — no hypothesis — so the whole file is tier-1 in offline
containers.  ``make test-faults`` runs it twice: once clean, once under
an env-driven ``REPRO_FAULTS`` plan (the ``env_plan`` test).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import merge_path as mp
from repro.kernels import ops
from repro.kernels.ssm_scan import ssm_scan_pallas, ssm_scan_ref
from repro.runtime import faults
from repro.runtime import resilience as res
from repro.runtime.resilience import FallbackWarning, GuardedDispatchError

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# every injected fault emits a FallbackWarning by design; individual tests
# assert on it explicitly with pytest.warns where the message matters
pytestmark = pytest.mark.filterwarnings(
    "ignore::repro.runtime.resilience.FallbackWarning"
)


@pytest.fixture(autouse=True)
def _clean_state():
    faults.reset_counters()
    res.reset_health()
    yield
    faults.reset_counters()
    res.reset_health()


def _tok_np(x) -> np.ndarray:
    return np.asarray(mp.total_order_keys(jnp.asarray(x)))


def _tok_stable_sort(x: np.ndarray) -> np.ndarray:
    """NumPy oracle for the repo's total-order (NaN-last) stable sort."""
    order = np.argsort(_tok_np(x), kind="stable")
    return x[order]


def _tree_equal(a, b) -> None:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.shape == y.shape and x.dtype == y.dtype
        if np.issubdtype(x.dtype, np.floating):
            assert np.array_equal(x, y, equal_nan=True)
        else:
            assert np.array_equal(x, y)


# ---------------------------------------------------------------------------
# injector unit tests
# ---------------------------------------------------------------------------


def test_plan_grammar():
    specs = faults.parse_plan(
        "launch:merge:0,2; nan:*:*; exchange:distributed_merge:1:window; vmem:sort*"
    )
    assert [s.cls for s in specs] == ["launch", "nan", "exchange", "vmem"]
    assert specs[0].op == "merge" and specs[0].indices == (0, 2)
    assert specs[1].op == "*" and specs[1].indices is None
    assert specs[2].indices == (1,) and specs[2].match == "window"
    assert specs[3].op == "sort*" and specs[3].indices is None
    with pytest.raises(ValueError):
        faults.parse_plan("explode:merge:0")
    with pytest.raises(ValueError):
        faults.parse_plan("launch")
    with pytest.raises(ValueError):
        faults.parse_plan("launch:merge:0:pallas:extra")


def test_should_fire_semantics():
    with faults.inject("launch:merge:*"):
        # a spec without a match never fires on the last attempt of a chain
        assert faults.should_fire("launch", "merge", 0, label="pallas-hier", last=False)
        assert not faults.should_fire("launch", "merge", 0, label="core", last=True)
        assert not faults.should_fire("launch", "sort", 0, label="pallas-hier")
    with faults.inject("launch:sort:1:pallas"):
        # an explicit match is a substring filter and ignores `last`
        assert not faults.should_fire("launch", "sort", 0, label="pallas-hier")
        assert faults.should_fire("launch", "sort", 1, label="pallas-hier", last=True)
        assert not faults.should_fire("launch", "sort", 1, label="core", last=True)
    assert not faults.should_fire("launch", "merge", 0, label="pallas-hier")


def test_inject_stacks_and_restores_counters():
    assert not faults.active() or os.environ.get("REPRO_FAULTS")
    base = faults.next_index("merge")
    with faults.inject("launch:merge:*"):
        assert faults.active()
        assert faults.next_index("merge") == 0  # counters snapshot to zero
        assert faults.should_fire("launch", "merge", 0, label="x")
        assert len(faults.fired_events()) == 1
    # counters and the fired log are restored on exit
    assert faults.next_index("merge") == base + 1
    assert len(faults.fired_events()) == 0


def test_nan_lace_deterministic():
    x = np.linspace(-1.0, 1.0, 64).astype(np.float32)
    a = np.asarray(faults.nan_lace(x, "salt"))
    b = np.asarray(faults.nan_lace(x, "salt"))
    c = np.asarray(faults.nan_lace(x, "other"))
    assert np.array_equal(a, b, equal_nan=True)
    assert np.isnan(a).sum() == max(1, x.size // 8)
    assert not np.array_equal(np.isnan(a), np.isnan(c))
    ints = np.arange(32, dtype=np.int32)
    assert faults.nan_lace(ints, "salt") is ints  # non-float: unchanged


def test_corrupt_breaks_sortedness():
    x = np.sort(np.random.default_rng(0).standard_normal(64).astype(np.float32))
    y = np.asarray(faults.corrupt(x))
    assert not np.all(np.diff(y) >= 0)
    assert np.array_equal(np.sort(y), x)  # a swap, not a rewrite
    const = np.zeros(8, np.float32)
    assert faults.corrupt(const) is const
    k, v = faults.corrupt((x, x.copy()))
    assert not np.all(np.diff(np.asarray(k)) >= 0)
    assert np.array_equal(np.asarray(v), x)  # values untouched


# ---------------------------------------------------------------------------
# guarded kernel ops: one bit-identity fuzz per fault class
# ---------------------------------------------------------------------------


def _ops_cases():
    rng = np.random.default_rng(7)
    a = np.sort(rng.standard_normal(192)).astype(np.float32)
    b = np.sort(rng.standard_normal(128)).astype(np.float32)
    av = rng.integers(0, 10_000, a.shape[0]).astype(np.int32)
    bv = rng.integers(0, 10_000, b.shape[0]).astype(np.int32)
    A = np.sort(rng.standard_normal((3, 96)).astype(np.float32), axis=1)
    B = np.sort(rng.standard_normal((3, 64)).astype(np.float32), axis=1)
    AV = rng.integers(0, 10_000, A.shape).astype(np.int32)
    BV = rng.integers(0, 10_000, B.shape).astype(np.int32)
    a_lens = rng.integers(0, A.shape[1] + 1, A.shape[0]).astype(np.int32)
    b_lens = rng.integers(0, B.shape[1] + 1, B.shape[0]).astype(np.int32)
    x = rng.standard_normal(256).astype(np.float32)
    X = rng.standard_normal((3, 128)).astype(np.float32)
    XV = rng.integers(0, 99, X.shape).astype(np.int32)
    x_lens = rng.integers(1, X.shape[1] + 1, X.shape[0]).astype(np.int32)
    runs = np.sort(rng.standard_normal((4, 64)).astype(np.float32), axis=1)
    j = jnp.asarray
    return [
        ("merge", lambda: ops.merge(j(a), j(b))),
        ("merge_kv", lambda: ops.merge_kv(j(a), j(av), j(b), j(bv))),
        ("merge_batched", lambda: ops.merge_batched(j(A), j(B))),
        ("merge_kv_batched",
         lambda: ops.merge_kv_batched(j(A), j(AV), j(B), j(BV))),
        ("merge_batched_ragged",
         lambda: ops.merge_batched_ragged(j(A), j(B), j(a_lens), j(b_lens))),
        ("merge_kv_batched_ragged",
         lambda: ops.merge_kv_batched_ragged(
             j(A), j(AV), j(B), j(BV), j(a_lens), j(b_lens))),
        ("sort", lambda: ops.sort(j(x))),
        ("sort_kv", lambda: ops.sort_kv(j(x), j(np.arange(x.size, dtype=np.int32)))),
        ("sort_batched", lambda: ops.sort_batched(j(X))),
        ("sort_kv_batched", lambda: ops.sort_kv_batched(j(X), j(XV))),
        ("merge_k", lambda: ops.merge_k(j(runs))),
        ("topk_batched", lambda: ops.topk_batched(j(X), 8)),
        ("topk_batched_ragged",
         lambda: ops.topk_batched_ragged(j(X), 8, j(x_lens))),
    ]


_OPS_CASES = _ops_cases()


@pytest.mark.parametrize("op,thunk", _OPS_CASES, ids=[c[0] for c in _OPS_CASES])
def test_launch_fault_degrades_bit_identical(op, thunk):
    """A wildcard launch fault burns every non-final attempt; the surviving
    oracle edge must reproduce the clean chain's output bit for bit."""
    clean = thunk()
    res.reset_health()
    with faults.inject(f"launch:{op}:*"):
        with pytest.warns(FallbackWarning, match="degraded"):
            degraded = thunk()
        rec = res.health(op)
        assert rec.fallbacks >= 1 and rec.launch_failures >= 1
        assert rec.served_by and "pallas" not in max(rec.served_by)
    _tree_equal(degraded, clean)


def test_vmem_fault_rejected_in_preflight():
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal(256).astype(np.float32))
    clean = ops.sort(x)
    res.reset_health()
    with faults.inject("vmem:sort:*:pallas"):
        with pytest.warns(FallbackWarning, match="degraded"):
            degraded = ops.sort(x)
        rec = res.health("sort")
        assert rec.precondition_rejects >= 1
        assert rec.served_by.get("core") == 1
    _tree_equal(degraded, clean)


def test_exchange_fault_caught_by_verifier():
    rng = np.random.default_rng(12)
    a = jnp.asarray(np.sort(rng.standard_normal(192).astype(np.float32)))
    b = jnp.asarray(np.sort(rng.standard_normal(128).astype(np.float32)))
    clean = ops.merge(a, b)
    res.reset_health()
    with faults.inject("exchange:merge:*:pallas-hier"):
        with pytest.warns(FallbackWarning, match="verify failed"):
            degraded = ops.merge(a, b)
        rec = res.health("merge")
        assert rec.verify_failures == 1
        assert rec.served_by.get("pallas-matrix") == 1
    _tree_equal(degraded, clean)


def test_nan_fault_sort_total_order_oracle():
    """NaN-laced keys must come out in total-order (NaN-last, stable)."""
    rng = np.random.default_rng(13)
    x = rng.standard_normal(256).astype(np.float32)
    with faults.inject("nan:sort:*"):
        out = np.asarray(ops.sort(jnp.asarray(x)))
    laced = np.asarray(faults.nan_lace(x, "sort:0:0"))
    assert np.isnan(laced).any()
    _tree_equal(out, _tok_stable_sort(laced))


def test_nan_fault_merge_repaired_by_resort():
    """Lacing breaks the sorted-input precondition of every merge route;
    only the terminal re-sort edge can serve, and it must match the
    total-order oracle on the laced operands exactly."""
    rng = np.random.default_rng(14)
    a = np.sort(rng.standard_normal(192).astype(np.float32))
    b = np.sort(rng.standard_normal(128).astype(np.float32))
    res.reset_health()
    with faults.inject("nan:merge:*"):
        out = np.asarray(ops.merge(jnp.asarray(a), jnp.asarray(b)))
        assert res.health("merge").served_by.get("core-resort") == 1
    la = np.asarray(faults.nan_lace(a, "merge:0:0"))
    lb = np.asarray(faults.nan_lace(b, "merge:0:1"))
    _tree_equal(out, _tok_stable_sort(np.concatenate([la, lb])))


def test_launch_fault_ssm_scan_degrades_to_ref():
    rng = np.random.default_rng(15)
    bsz, s, d, st = 1, 32, 16, 4
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (bsz, s, d)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((bsz, s, d)).astype(np.float32))
    bmat = jnp.asarray(rng.standard_normal((bsz, s, st)).astype(np.float32))
    cmat = jnp.asarray(rng.standard_normal((bsz, s, st)).astype(np.float32))
    a = jnp.asarray(-np.abs(rng.standard_normal((d, st))).astype(np.float32))
    y_ref, h_ref = ssm_scan_ref(dt, x, bmat, cmat, a)
    res.reset_health()
    with faults.inject("launch:ssm_scan_pallas:*"):
        with pytest.warns(FallbackWarning, match="degraded"):
            y, h = ssm_scan_pallas(dt, x, bmat, cmat, a)
        assert res.health("ssm_scan_pallas").served_by.get("core-ref") == 1
    _tree_equal((y, h), (y_ref, h_ref))


def test_exhausted_chain_raises_with_log():
    with faults.inject("launch:merge:*:"):
        # no-match wildcard spares the oracle; force it too with a 2nd clause
        with faults.inject("launch:merge:*:core"):
            with pytest.raises(GuardedDispatchError) as exc:
                ops.merge(jnp.arange(8.0), jnp.arange(8.0))
            assert "core-resort" in str(exc.value)
    assert res.health("merge").exhausted == 1


def test_guard_disabled_env_bypasses(monkeypatch):
    monkeypatch.setenv("REPRO_GUARD", "0")
    assert not res.guard_enabled()
    rng = np.random.default_rng(16)
    x = jnp.asarray(rng.standard_normal(128).astype(np.float32))
    with faults.inject("launch:sort:*"):
        out = ops.sort(x)  # guard off: primary path runs, no fault hooks
    assert res.health("sort").calls == 0
    _tree_equal(out, jnp.sort(x))
    monkeypatch.delenv("REPRO_GUARD")
    assert res.guard_enabled()


# ---------------------------------------------------------------------------
# env-driven plan (`make test-faults` re-runs only this under REPRO_FAULTS)
# ---------------------------------------------------------------------------


def test_env_plan_end_to_end():
    """Under the Makefile's REPRO_FAULTS plan the first call of each named
    op degrades and still matches its oracle; later calls run clean."""
    plan = os.environ.get("REPRO_FAULTS", "")
    if not plan:
        pytest.skip("REPRO_FAULTS not set (run via `make test-faults`)")
    assert faults.active()
    rng = np.random.default_rng(17)
    a = np.sort(rng.standard_normal(192).astype(np.float32))
    b = np.sort(rng.standard_normal(128).astype(np.float32))
    x = rng.standard_normal(256).astype(np.float32)

    # call index 0: the env plan fires (launch:merge:0 / launch:sort:0)
    faults.reset_counters()
    m0 = np.asarray(ops.merge(jnp.asarray(a), jnp.asarray(b)))
    s0 = np.asarray(ops.sort(jnp.asarray(x)))
    assert {e.op for e in faults.fired_events()} >= {"merge", "sort"}
    # call index 1: clean
    m1 = np.asarray(ops.merge(jnp.asarray(a), jnp.asarray(b)))
    s1 = np.asarray(ops.sort(jnp.asarray(x)))
    oracle_m = np.sort(np.concatenate([a, b]), kind="stable")
    for got in (m0, m1):
        _tree_equal(got, oracle_m)
    for got in (s0, s1):
        _tree_equal(got, np.sort(x, kind="stable"))
    assert res.health("merge").fallbacks >= 1
    assert res.health("sort").fallbacks >= 1


# ---------------------------------------------------------------------------
# serving engine: graceful degradation
# ---------------------------------------------------------------------------


def _make_engine(**kw):
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving.engine import ServingEngine

    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_params(cfg, jax.random.key(0))
    return cfg, ServingEngine(cfg, params, batch=2, max_seq=32, **kw)


def _requests(cfg, n, rng, **kw):
    from repro.serving.engine import Request

    return [
        Request(
            uid=i,
            prompt=rng.integers(1, cfg.vocab_size, 4).astype(np.int32),
            max_new_tokens=2,
            temperature=0.0,
            **kw,
        )
        for i in range(n)
    ]


def test_serving_shed_and_deadline_without_decode():
    """Queue shedding and deadline expiry never touch the decode path, so
    they work even while the backend is down (permanent injected fault)."""
    cfg, eng = _make_engine(max_pending=1)
    rng = np.random.default_rng(0)
    r1, r2 = _requests(cfg, 2, rng, deadline_ticks=2)
    with pytest.warns(FallbackWarning, match="shed"):
        eng.submit(r1)
        eng.submit(r2)  # queue full -> shed at submit time
    assert r2.status == "shed" and "queue full" in r2.reason
    with faults.inject("launch:serving.decode:*"):
        rep = eng.run_until_done(max_ticks=10)
    assert rep.statuses[r1.uid] == "timed_out"
    assert "deadline_ticks=2" in rep.reasons[r1.uid]
    assert rep.shed == 1 and rep.timed_out == 1 and rep.completed == 0
    assert not rep.ok()
    assert len(eng.done) == 2  # nothing dropped silently


def test_serving_transient_fault_retries_and_completes():
    """A transient decode fault costs retries + backoff ticks but every
    request still completes — zero drops, partials never surface."""
    cfg, eng = _make_engine(max_retries=3, backoff_base=1, backoff_cap=4)
    rng = np.random.default_rng(1)
    reqs = _requests(cfg, 3, rng)
    for r in reqs:
        eng.submit(r)
    with faults.inject("launch:serving.decode:1"):
        rep = eng.run_until_done(max_ticks=200)
    assert rep.completed == len(reqs) and rep.ok()
    assert rep.retries == 1
    assert sorted(rep.statuses) == [r.uid for r in reqs]
    for r in reqs:
        assert r.status == "completed"
        assert len(r.generated) == r.max_new_tokens


def test_serving_permanent_fault_never_wedges():
    """A permanently failing backend sheds the queue with reasons instead
    of hanging; the engine survives and the report accounts for all."""
    cfg, eng = _make_engine(max_retries=2)
    rng = np.random.default_rng(2)
    reqs = _requests(cfg, 2, rng)
    for r in reqs:
        eng.submit(r)
    with faults.inject("launch:serving.decode:*"):
        rep = eng.run_until_done(max_ticks=30)
    assert rep.ticks == 30 and not rep.ok()
    assert rep.completed == 0
    assert rep.shed + rep.timed_out + rep.failed == len(reqs)
    assert len(eng.done) == len(reqs)
    for r in reqs:
        assert rep.reasons[r.uid]  # every terminal status carries a reason
    # the engine recovered its retry state: a clean tick is a no-op, not a throw
    eng.step()


# ---------------------------------------------------------------------------
# distributed chains (subprocess, 8 fake CPU devices)
# ---------------------------------------------------------------------------


def run_with_devices(code: str, n: int = 8, timeout: int = 600) -> None:
    # mirrors tests/test_distributed.py: fake device count in a subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("REPRO_FAULTS", None)  # the inline scripts inject their own plans
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"


def test_distributed_faults_multi_device():
    run_with_devices("""
        import warnings
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed as D
        from repro.runtime import faults
        from repro.runtime import resilience as res
        warnings.simplefilter("ignore")
        rng = np.random.default_rng(0)

        # merge: window exchange down -> gather serves, bit-identical
        a = np.sort(rng.standard_normal(512)).astype(np.float32)
        b = np.sort(rng.standard_normal(256)).astype(np.float32)
        clean = np.asarray(D.distributed_merge(jnp.array(a), jnp.array(b)))
        assert np.array_equal(clean, np.sort(np.concatenate([a, b])))
        res.reset_health()
        with faults.inject("launch:distributed_merge:*:window"):
            out = np.asarray(D.distributed_merge(jnp.array(a), jnp.array(b)))
        assert np.array_equal(out, clean)
        assert res.health("distributed_merge").served_by.get("gather") == 1

        # merge: corrupted window exchange is caught by the always-on verify
        res.reset_health()
        with faults.inject("exchange:distributed_merge:*:window"):
            out = np.asarray(D.distributed_merge(jnp.array(a), jnp.array(b)))
        assert np.array_equal(out, clean)
        assert res.health("distributed_merge").verify_failures == 1

        # sort: sampled splitters down -> capacity escalation (shape grows;
        # slice by the returned counts), still the exact global sort
        x = rng.standard_normal(2048).astype(np.float32)
        res.reset_health()
        with faults.inject("launch:distributed_sort:*:sample"):
            s, cnt, ovf = D.distributed_sort(jnp.array(x))
        assert res.health("distributed_sort").served_by.get("capacity-2x") == 1
        s, cnt = np.asarray(s), np.asarray(cnt)
        cap = s.shape[0] // cnt.size
        got = np.concatenate([s[i*cap:i*cap+cnt[i]] for i in range(cnt.size)])
        assert np.array_equal(got, np.sort(x))

        # sort: every exchange route down -> single-host total-order re-sort
        res.reset_health()
        with faults.inject("launch:distributed_sort:*"):
            s, cnt, ovf = D.distributed_sort(jnp.array(x))
        assert res.health("distributed_sort").served_by.get("core-resort") == 1
        assert np.array_equal(np.asarray(s)[:int(np.asarray(cnt).sum())], np.sort(x))

        # topk: butterfly down -> gather, then everything down -> core
        clean_v, clean_i = D.distributed_topk(jnp.array(x), 16)
        res.reset_health()
        with faults.inject("launch:distributed_topk:*:butterfly"):
            v, i = D.distributed_topk(jnp.array(x), 16)
        assert np.array_equal(np.asarray(v), np.asarray(clean_v))
        assert np.array_equal(np.asarray(i), np.asarray(clean_i))
        assert res.health("distributed_topk").served_by.get("gather") == 1
        res.reset_health()
        with faults.inject("launch:distributed_topk:*"):
            v, i = D.distributed_topk(jnp.array(x), 16)
        assert np.array_equal(np.asarray(v), np.asarray(clean_v))
        assert np.array_equal(np.asarray(i), np.asarray(clean_i))
        assert res.health("distributed_topk").served_by.get("core-topk") == 1
        print("ok")
    """)
