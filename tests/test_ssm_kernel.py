"""Fused SSM-scan Pallas kernel vs oracle: shape/dtype/chunk sweeps."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan import (
    fused_hbm_bytes,
    ssm_scan_pallas,
    ssm_scan_ref,
    xla_scan_hbm_bytes,
)


def _inputs(B, S, D, st, dtype, seed=0):
    rng = np.random.default_rng(seed)
    dt = jnp.asarray(jax.nn.softplus(rng.standard_normal((B, S, D))).astype(np.float32)).astype(dtype)
    x = jnp.asarray(rng.standard_normal((B, S, D)).astype(np.float32)).astype(dtype)
    bm = jnp.asarray(rng.standard_normal((B, S, st)).astype(np.float32)).astype(dtype)
    cm = jnp.asarray(rng.standard_normal((B, S, st)).astype(np.float32)).astype(dtype)
    a = -jnp.exp(jnp.asarray(rng.standard_normal((D, st)).astype(np.float32)))
    return dt, x, bm, cm, a


@pytest.mark.parametrize("B,S,D,st,chunk,d_tile", [
    (1, 32, 16, 4, 8, 16),
    (2, 64, 32, 8, 16, 16),
    (2, 128, 48, 16, 32, 24),
    (1, 256, 8, 2, 256, 8),  # single chunk, tiny dims
])
def test_fused_scan_matches_oracle(B, S, D, st, chunk, d_tile):
    dt, x, bm, cm, a = _inputs(B, S, D, st, jnp.float32)
    y, h = ssm_scan_pallas(dt, x, bm, cm, a, chunk=chunk, d_tile=d_tile)
    yr, hr = ssm_scan_ref(dt, x, bm, cm, a)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=3e-5, atol=3e-5)


def test_fused_scan_bf16_inputs():
    dt, x, bm, cm, a = _inputs(2, 64, 32, 8, jnp.bfloat16)
    y, h = ssm_scan_pallas(dt, x, bm, cm, a, chunk=16, d_tile=16)
    yr, hr = ssm_scan_ref(dt, x, bm, cm, a)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), rtol=3e-2, atol=3e-2
    )


def test_traffic_model_reduction():
    """The kernel's analytic HBM traffic is >=50x below the XLA scan path
    at falcon-mamba train_4k per-device dimensions."""
    B, S, D, st = 16, 4096, 512, 16
    fused = fused_hbm_bytes(B, S, D, st)
    xla = xla_scan_hbm_bytes(B, S, D, st)
    assert xla / fused > 50, (xla, fused)
