"""Fused SSM-scan Pallas kernel vs oracle: shape/dtype/chunk sweeps, and
gradient checks of the chunk-recompute ``custom_vjp`` backward kernel
against ``jax.grad`` of the pure-JAX oracle."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan import (
    bwd_hbm_bytes,
    fused_hbm_bytes,
    ssm_scan_pallas,
    ssm_scan_ref,
    xla_scan_hbm_bytes,
)
from grad_utils import fd_check, vjp_compare


def _inputs(B, S, D, st, dtype, seed=0):
    rng = np.random.default_rng(seed)
    dt = jnp.asarray(jax.nn.softplus(rng.standard_normal((B, S, D))).astype(np.float32)).astype(dtype)
    x = jnp.asarray(rng.standard_normal((B, S, D)).astype(np.float32)).astype(dtype)
    bm = jnp.asarray(rng.standard_normal((B, S, st)).astype(np.float32)).astype(dtype)
    cm = jnp.asarray(rng.standard_normal((B, S, st)).astype(np.float32)).astype(dtype)
    a = -jnp.exp(jnp.asarray(rng.standard_normal((D, st)).astype(np.float32)))
    return dt, x, bm, cm, a


@pytest.mark.parametrize("B,S,D,st,chunk,d_tile", [
    (1, 32, 16, 4, 8, 16),
    (2, 64, 32, 8, 16, 16),
    (2, 128, 48, 16, 32, 24),
    (1, 256, 8, 2, 256, 8),  # single chunk, tiny dims
])
def test_fused_scan_matches_oracle(B, S, D, st, chunk, d_tile):
    dt, x, bm, cm, a = _inputs(B, S, D, st, jnp.float32)
    y, h = ssm_scan_pallas(dt, x, bm, cm, a, chunk=chunk, d_tile=d_tile)
    yr, hr = ssm_scan_ref(dt, x, bm, cm, a)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=3e-5, atol=3e-5)


def test_fused_scan_bf16_inputs():
    dt, x, bm, cm, a = _inputs(2, 64, 32, 8, jnp.bfloat16)
    y, h = ssm_scan_pallas(dt, x, bm, cm, a, chunk=16, d_tile=16)
    yr, hr = ssm_scan_ref(dt, x, bm, cm, a)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), rtol=3e-2, atol=3e-2
    )


@pytest.mark.parametrize("B,S,D,st,chunk,d_tile", [
    (1, 24, 8, 4, 8, 8),     # chunk-divisible, single d-tile
    (2, 21, 8, 4, 8, 8),     # S straddles a chunk boundary (identity pad)
    (1, 33, 16, 4, 16, 8),   # straddle + multiple d-tiles (dA/g scratch)
    (2, 16, 8, 2, 16, 8),    # single chunk (no checkpoint reload)
])
def test_fused_scan_grads_match_oracle(B, S, D, st, chunk, d_tile):
    """Backward kernel (recompute from chunk checkpoints) vs
    ``jax.grad(ssm_scan_ref)``, cotangents on BOTH outputs (y, h_final)."""
    dt, x, bm, cm, a = _inputs(B, S, D, st, jnp.float32)
    vjp_compare(
        lambda *args: ssm_scan_pallas(*args, chunk=chunk, d_tile=d_tile),
        ssm_scan_ref,
        [dt, x, bm, cm, a],
        bit=False, rtol=2e-4, atol=2e-4,
    )


def test_fused_scan_grads_bf16():
    """bf16 activations: backward accumulates f32, grads land near the
    f32 oracle grads (bf16-forward tolerance)."""
    dt, x, bm, cm, a = _inputs(1, 40, 16, 4, jnp.bfloat16, seed=3)
    vjp_compare(
        lambda *args: ssm_scan_pallas(*args, chunk=16, d_tile=16),
        ssm_scan_ref,
        [dt, x, bm, cm, a],
        bit=False, rtol=6e-2, atol=6e-2,
    )


def test_fused_scan_grad_y_only_cotangent():
    """Training uses only y (h_final dropped): dh_fin = 0 path."""
    dt, x, bm, cm, a = _inputs(2, 12, 8, 4, jnp.float32, seed=5)

    def loss_k(*args):
        y, _ = ssm_scan_pallas(*args, chunk=8, d_tile=8)
        return jnp.sum(y * y)

    def loss_r(*args):
        y, _ = ssm_scan_ref(*args)
        return jnp.sum(y * y)

    gk = jax.grad(loss_k, argnums=(0, 1, 2, 3, 4))(dt, x, bm, cm, a)
    gr = jax.grad(loss_r, argnums=(0, 1, 2, 3, 4))(dt, x, bm, cm, a)
    for k, r in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(k), np.asarray(r), rtol=2e-4, atol=2e-4)


def _ref_native_dtype(dt, x, bmat, cmat, a):
    """ssm_scan_ref's recurrence in the inputs' own dtype — identical math
    without the internal f32 pin, so it runs in f64 under ``enable_x64``
    (the pin makes ``lax.scan`` carries mix f32/f64 there)."""
    bsz, s, d = x.shape
    st = bmat.shape[-1]
    decay = jnp.exp(dt[..., None] * a[None, None])
    upd = (dt * x)[..., None] * bmat[:, :, None, :]

    def step(h, inputs):
        dec, up, c = inputs
        h = dec * h + up
        return h, jnp.sum(h * c[:, None, :], axis=-1)

    h0 = jnp.zeros((bsz, d, st), x.dtype)
    h_final, ys = jax.lax.scan(
        step, h0,
        (decay.transpose(1, 0, 2, 3), upd.transpose(1, 0, 2, 3),
         cmat.transpose(1, 0, 2)),
    )
    return ys.transpose(1, 0, 2), h_final


def test_ssm_oracle_fd_check():
    """f64 central differences pin the oracle recurrence the kernel is
    tested against (both outputs contracted with a random cotangent)."""
    dt, x, bm, cm, a = _inputs(1, 6, 3, 2, jnp.float32, seed=7)
    # same math: at f32 the native-dtype form IS ssm_scan_ref
    y0, h0 = ssm_scan_ref(dt, x, bm, cm, a)
    y1, h1 = _ref_native_dtype(dt, x, bm, cm, a)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1), rtol=1e-6, atol=1e-6)
    fd_check(_ref_native_dtype, [dt, x, bm, cm, a], eps=1e-5, rtol=1e-5, atol=1e-7)


def test_traffic_model_reduction():
    """The kernel's analytic HBM traffic is >=50x below the XLA scan path
    at falcon-mamba train_4k per-device dimensions."""
    B, S, D, st = 16, 4096, 512, 16
    fused = fused_hbm_bytes(B, S, D, st)
    xla = xla_scan_hbm_bytes(B, S, D, st)
    assert xla / fused > 50, (xla, fused)
