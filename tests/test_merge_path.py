"""Property + unit tests for the core Merge Path algorithms."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    diagonal_intersections,
    merge,
    merge_kv,
    merge_sort,
    merge_sort_kv,
    partitioned_merge,
    segmented_merge,
    segmented_merge_kv,
    stable_argsort,
    topk_desc,
)

# bounded so int sentinels never collide with payloads
ints = st.integers(min_value=-10_000, max_value=10_000)


def sorted_arr(draw, n, dtype=np.int32):
    xs = draw(st.lists(ints, min_size=n, max_size=n))
    return np.sort(np.array(xs, dtype=dtype))


@st.composite
def two_sorted(draw, max_n=200):
    na = draw(st.integers(0, max_n))
    nb = draw(st.integers(0, max_n))
    return sorted_arr(draw, na), sorted_arr(draw, nb)


@settings(max_examples=60, deadline=None)
@given(two_sorted())
def test_merge_is_stable_sorted_permutation(ab):
    a, b = ab
    out = np.asarray(merge(jnp.array(a), jnp.array(b)))
    ref = np.sort(np.concatenate([a, b]), kind="stable")
    assert out.shape == (len(a) + len(b),)
    np.testing.assert_array_equal(out, ref)


@settings(max_examples=40, deadline=None)
@given(two_sorted(max_n=120), st.integers(0, 500))
def test_diagonal_intersection_invariants(ab, dseed):
    a, b = ab
    n = len(a) + len(b)
    d = np.array([dseed % (n + 1)]) if n else np.array([0])
    ai = int(np.asarray(diagonal_intersections(jnp.array(a), jnp.array(b), jnp.array(d)))[0])
    bi = int(d[0]) - ai
    assert 0 <= ai <= len(a) and 0 <= bi <= len(b)
    # the partition is a valid merge-path point: everything consumed is <=
    # everything not yet consumed (ties broken toward A)
    if ai > 0 and bi < len(b):
        assert a[ai - 1] <= b[bi]
    if bi > 0 and ai < len(a):
        assert b[bi - 1] < a[ai]


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 6), st.data())
def test_partitioned_merge_matches_merge(logp, data):
    p = 1 << logp
    # sizes chosen so |A|+|B| divisible by p
    total = p * data.draw(st.integers(1, 16))
    na = data.draw(st.integers(0, total))
    a = np.sort(np.array(data.draw(st.lists(ints, min_size=na, max_size=na)), np.int32))
    nb = total - na
    b = np.sort(np.array(data.draw(st.lists(ints, min_size=nb, max_size=nb)), np.int32))
    out = np.asarray(partitioned_merge(jnp.array(a), jnp.array(b), p))
    np.testing.assert_array_equal(out, np.sort(np.concatenate([a, b]), kind="stable"))


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_segmented_merge_matches(data):
    seg = data.draw(st.sampled_from([4, 8, 16, 32]))
    nseg = data.draw(st.integers(1, 8))
    total = seg * nseg
    na = data.draw(st.integers(0, total))
    a = np.sort(np.array(data.draw(st.lists(ints, min_size=na, max_size=na)), np.int32))
    b = np.sort(np.array(data.draw(st.lists(ints, min_size=total - na, max_size=total - na)), np.int32))
    out = np.asarray(segmented_merge(jnp.array(a), jnp.array(b), seg))
    np.testing.assert_array_equal(out, np.sort(np.concatenate([a, b]), kind="stable"))


@settings(max_examples=40, deadline=None)
@given(st.lists(ints, min_size=0, max_size=500))
def test_merge_sort(xs):
    x = np.array(xs, np.int32)
    out = np.asarray(merge_sort(jnp.array(x)))
    np.testing.assert_array_equal(out, np.sort(x))


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 7), min_size=1, max_size=300))
def test_stable_argsort_matches_numpy(keys):
    k = np.array(keys, np.int32)
    perm = np.asarray(stable_argsort(jnp.array(k)))
    np.testing.assert_array_equal(perm, np.argsort(k, kind="stable"))


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False, allow_subnormal=False, width=32),
                min_size=1, max_size=200),
       st.integers(1, 20))
def test_topk_matches_lax(xs, k):
    # normalize -0.0 -> 0.0: lax.top_k uses IEEE total order (0.0 > -0.0)
    # while merge-path compares them equal and breaks ties by index.
    x = np.array(xs, np.float32) + 0.0
    k = min(k, len(x))
    v, i = topk_desc(jnp.array(x), k)
    rv, ri = jax.lax.top_k(jnp.array(x), k)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


def test_merge_kv_stability_a_priority():
    ak = jnp.array([1, 1, 2], jnp.int32)
    av = jnp.array([10, 11, 12], jnp.int32)
    bk = jnp.array([1, 2, 2], jnp.int32)
    bv = jnp.array([20, 21, 22], jnp.int32)
    ko, vo = merge_kv(ak, av, bk, bv)
    np.testing.assert_array_equal(np.asarray(ko), [1, 1, 1, 2, 2, 2])
    np.testing.assert_array_equal(np.asarray(vo), [10, 11, 20, 12, 21, 22])


def test_merge_sort_kv_stable():
    rng = np.random.default_rng(0)
    k = rng.integers(0, 5, 257).astype(np.int32)
    v = np.arange(257, dtype=np.int32)
    ks, vs = merge_sort_kv(jnp.array(k), jnp.array(v))
    order = np.argsort(k, kind="stable")
    np.testing.assert_array_equal(np.asarray(ks), k[order])
    np.testing.assert_array_equal(np.asarray(vs), v[order])


def test_segmented_merge_kv():
    rng = np.random.default_rng(1)
    ak = np.sort(rng.integers(0, 50, 48)).astype(np.int32)
    bk = np.sort(rng.integers(0, 50, 16)).astype(np.int32)
    av = np.arange(48, dtype=np.float32)
    bv = 100 + np.arange(16, dtype=np.float32)
    ko, vo = segmented_merge_kv(jnp.array(ak), jnp.array(av), jnp.array(bk), jnp.array(bv), 16)
    rk, rv = jax.lax.sort(
        (jnp.concatenate([jnp.array(ak), jnp.array(bk)]),
         jnp.concatenate([jnp.array(av), jnp.array(bv)])),
        is_stable=True, num_keys=1)
    np.testing.assert_array_equal(np.asarray(ko), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(vo), np.asarray(rv))


def test_empty_and_degenerate():
    e = jnp.array([], jnp.int32)
    a = jnp.array([1, 2, 3], jnp.int32)
    np.testing.assert_array_equal(np.asarray(merge(a, e)), [1, 2, 3])
    np.testing.assert_array_equal(np.asarray(merge(e, a)), [1, 2, 3])
    np.testing.assert_array_equal(np.asarray(merge_sort(e)), [])
    np.testing.assert_array_equal(np.asarray(merge_sort(jnp.array([5], jnp.int32))), [5])
