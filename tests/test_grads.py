"""Gradient tests for the kernel-path permutation VJPs.

The merge-path sort is a *stable permutation* (Siebert & Träff's co-rank
partition makes it well-defined even under duplicate keys), so the
kernel route's ``custom_vjp`` — forward saves the stable argsort,
backward is one inverse-gather scatter — must be **bit-identical** to
``jax.grad`` through the pure-JAX oracle route for any input, including
duplicate keys, ragged ``lens=``, sentinel-tied keys, and non-pow2
(padding-path) sizes.

Fuzzing comes in two tiers, mirroring ``test_merge_path.py``'s optional
hypothesis: property tests run where ``hypothesis`` is importable, and a
seeded deterministic sweep over the same regimes (duplicate-heavy value
pool including the f32 max-sentinel, ragged lens with empty rows,
non-pow2 n) always runs.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.core as core
import repro.kernels.ops as kops
from grad_utils import fd_check, vjp_compare

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: the seeded sweep below still runs
    st = None

F32_MAX = float(np.finfo(np.float32).max)

# small value pool => heavy duplication; includes the f32 max-sentinel
# value so sentinel-tied keys are fuzzed too
VAL_POOL = np.array([-2.5, -1.0, 0.0, 0.5, 1.0, 1.5, F32_MAX], np.float32)


def _pool_draw(rng, shape):
    return jnp.asarray(rng.choice(VAL_POOL, size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# deterministic seeded sweep (always runs)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed,n", [(0, 2), (1, 7), (4, 33)])
def test_sort_grad_bit_identical(seed, n):
    x = _pool_draw(np.random.default_rng(seed), (n,))
    vjp_compare(lambda v: kops.sort(v), lambda v: core.merge_sort(v), [x], seed=seed)


@pytest.mark.parametrize("seed,n", [(0, 5), (2, 29)])
def test_sort_kv_grads_bit_identical(seed, n):
    rng = np.random.default_rng(seed)
    keys = _pool_draw(rng, (n,))
    vals = jnp.asarray(rng.standard_normal(n), jnp.float32)
    vjp_compare(
        lambda k, v: kops.sort_kv(k, v),
        lambda k, v: core.merge_sort_kv(k, v),
        [keys, vals],
        seed=seed,
    )


@pytest.mark.parametrize("seed,n", [(0, 13)])
def test_sort_kv_int_payload_key_grads(seed, n):
    """Int payloads take the float0 branch; key grads still bit-match,
    and the tied-key permutation matches the oracle (stability)."""
    keys = _pool_draw(np.random.default_rng(seed), (n,))
    vals = jnp.arange(n, dtype=jnp.int32)[::-1]

    vjp_compare(
        lambda k: kops.sort_kv(k, vals)[0],
        lambda k: core.merge_sort_kv(k, vals)[0],
        [keys],
        seed=seed,
    )
    np.testing.assert_array_equal(
        np.asarray(kops.sort_kv(keys, vals)[1]),
        np.asarray(core.merge_sort_kv(keys, vals)[1]),
    )


@pytest.mark.parametrize("seed,b,n", [(0, 1, 9), (2, 2, 24)])
def test_sort_batched_grad_bit_identical(seed, b, n):
    x = _pool_draw(np.random.default_rng(seed), (b, n))
    vjp_compare(
        lambda v: kops.sort_batched(v), lambda v: core.merge_sort_batched(v), [x],
        seed=seed,
    )


@pytest.mark.parametrize("seed,b,n", [(0, 2, 11), (1, 3, 24)])
def test_sort_kv_batched_grads_bit_identical(seed, b, n):
    rng = np.random.default_rng(seed)
    keys = _pool_draw(rng, (b, n))
    vals = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    vjp_compare(
        lambda k, v: kops.sort_kv_batched(k, v),
        lambda k, v: core.merge_sort_kv_batched(k, v),
        [keys, vals],
        seed=seed,
    )


@pytest.mark.parametrize("seed,b,n,k", [(0, 2, 12, 3), (2, 1, 9, 20)])
def test_topk_batched_grad_bit_identical(seed, b, n, k):
    x = _pool_draw(np.random.default_rng(seed), (b, n))
    vjp_compare(
        lambda v: kops.topk_batched(v, k)[0],
        lambda v: core.topk_batched(v, k)[0],
        [x],
        seed=seed,
    )


@pytest.mark.parametrize(
    "seed,b,n,k,lens",
    [
        (0, 3, 12, 4, (0, 5, 12)),   # empty row + partial + full
        (1, 2, 9, 3, (1, 9)),        # non-pow2 n
    ],
)
def test_topk_batched_ragged_grads(seed, b, n, k, lens):
    """Ragged grads bit-match the oracle AND masked columns are zero."""
    x = _pool_draw(np.random.default_rng(seed), (b, n))
    lens = jnp.asarray(lens, jnp.int32)
    g = vjp_compare(
        lambda v: kops.topk_batched_ragged(v, k, lens)[0],
        lambda v: core.topk_batched_ragged(v, k, lens)[0],
        [x],
        seed=seed,
    )
    dx = np.asarray(g[0])
    cols = np.arange(n)[None, :]
    masked = cols >= np.asarray(lens)[:, None]
    assert np.all(dx[masked] == 0.0), "cotangent leaked into masked (ragged) slots"


def test_sort_nonpow2_kernel_round_grad():
    """n=192 with tile=128: pow2-pad path + a wide Pallas round under AD."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.choice([-1.0, 0.0, 0.25, 1.0], size=192), jnp.float32)
    vjp_compare(
        lambda v: kops.sort(v, tile=128, leaf=32),
        lambda v: core.merge_sort(v),
        [x],
    )


def test_sort_kv_batched_kernel_round_grad():
    """Wide flat-round kernel engaged for a batched kv sort under AD."""
    rng = np.random.default_rng(1)
    keys = jnp.asarray(rng.choice([0.0, 1.0, 2.0], size=(2, 192)), jnp.float32)
    vals = jnp.asarray(rng.standard_normal((2, 192)), jnp.float32)
    vjp_compare(
        lambda k, v: kops.sort_kv_batched(k, v, tile=128, leaf=64),
        lambda k, v: core.merge_sort_kv_batched(k, v),
        [keys, vals],
    )


def test_sort_oracle_fd_check():
    """f64 central differences validate the oracle route the kernel is
    compared against (away from ties, where sort is differentiable)."""
    x = jnp.asarray([3.0, -1.5, 0.25, 7.0, -4.0, 2.0, 0.75, -0.5], jnp.float32)
    fd_check(lambda v: core.merge_sort(v), [x], rtol=1e-6, atol=1e-9)


def test_topk_oracle_fd_check():
    x = jnp.asarray([[3.0, -1.5, 0.25, 7.0, -4.0, 2.0]], jnp.float32)
    fd_check(lambda v: core.topk_batched(v, 3)[0], [x], rtol=1e-6, atol=1e-9)


def test_moe_dispatch_pallas_grads_match_oracle_route():
    """moe_apply grads on merge_path_pallas == merge_path, bit-identical.

    seq*k = 512 slots exceeds the min int tile, so the flat Pallas round
    actually runs inside the differentiated forward.
    """
    import dataclasses
    from repro.configs.registry import get_config
    from repro.models import moe as moe_mod

    cfg = get_config("phi35-moe").reduced()
    cfg_k = dataclasses.replace(cfg, moe_dispatch="merge_path_pallas")
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 256, cfg.d_model), jnp.float32)

    def loss(p, xx, c):
        return jnp.sum(moe_mod.moe_apply(p, xx, c) ** 2)

    (l_o, g_o) = jax.value_and_grad(loss, argnums=(0, 1))(params, x, cfg)
    (l_k, g_k) = jax.value_and_grad(loss, argnums=(0, 1))(params, x, cfg_k)
    assert float(l_o) == float(l_k)
    for lo, lk in zip(jax.tree.leaves(g_o), jax.tree.leaves(g_k)):
        np.testing.assert_array_equal(np.asarray(lo), np.asarray(lk))
    assert all(bool(jnp.any(l != 0)) for l in jax.tree.leaves(g_k))


# ---------------------------------------------------------------------------
# hypothesis property tests (run where hypothesis is available)
# ---------------------------------------------------------------------------

if st is not None:
    key_vals = st.sampled_from([float(v) for v in VAL_POOL])

    def _farr(vals):
        return jnp.asarray(np.array(vals, np.float32))

    @st.composite
    def dup_keys(draw, min_n=2, max_n=48):
        n = draw(st.integers(min_n, max_n))
        return _farr(draw(st.lists(key_vals, min_size=n, max_size=n)))

    @st.composite
    def dup_keys_batched(draw, max_b=3, max_n=24):
        b = draw(st.integers(1, max_b))
        n = draw(st.integers(2, max_n))
        rows = [draw(st.lists(key_vals, min_size=n, max_size=n)) for _ in range(b)]
        return _farr(rows)

    @settings(max_examples=40)
    @given(dup_keys())
    def test_sort_grad_bit_identical_prop(x):
        vjp_compare(lambda v: kops.sort(v), lambda v: core.merge_sort(v), [x])

    @settings(max_examples=30)
    @given(dup_keys())
    def test_sort_kv_grads_bit_identical_prop(keys):
        rng = np.random.default_rng(keys.shape[0])
        vals = jnp.asarray(rng.standard_normal(keys.shape), jnp.float32)
        vjp_compare(
            lambda k, v: kops.sort_kv(k, v),
            lambda k, v: core.merge_sort_kv(k, v),
            [keys, vals],
        )

    @settings(max_examples=30)
    @given(dup_keys_batched())
    def test_sort_kv_batched_grads_bit_identical_prop(keys):
        rng = np.random.default_rng(keys.shape[1])
        vals = jnp.asarray(rng.standard_normal(keys.shape), jnp.float32)
        vjp_compare(
            lambda k, v: kops.sort_kv_batched(k, v),
            lambda k, v: core.merge_sort_kv_batched(k, v),
            [keys, vals],
        )

    @settings(max_examples=30)
    @given(dup_keys_batched(), st.integers(1, 8), st.data())
    def test_topk_batched_ragged_grads_prop(x, k, data):
        bsz, n = x.shape
        lens = jnp.asarray(
            [data.draw(st.integers(0, n), label=f"len{i}") for i in range(bsz)],
            jnp.int32,
        )
        g = vjp_compare(
            lambda v: kops.topk_batched_ragged(v, k, lens)[0],
            lambda v: core.topk_batched_ragged(v, k, lens)[0],
            [x],
        )
        dx = np.asarray(g[0])
        masked = np.arange(n)[None, :] >= np.asarray(lens)[:, None]
        assert np.all(dx[masked] == 0.0)
