"""Pallas kernel validation: interpret-mode allclose sweeps vs ref.py oracles."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import merge_pallas, merge_kv_pallas, ops, ref


SHAPES = [
    (1000, 1048, 256),
    (513, 511, 128),
    (64, 2000, 256),
    (2048, 0, 128),
    (0, 512, 128),
    (1, 1, 128),
    (4096, 4096, 512),
    (127, 3000, 512),
]

DTYPES = [np.int32, np.float32, np.dtype(jnp.bfloat16)]


def _sorted(rng, n, dtype):
    if np.dtype(dtype) == np.int32:
        return np.sort(rng.integers(-1000, 1000, n)).astype(np.int32)
    x = np.sort(rng.standard_normal(n)).astype(np.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("na,nb,tile", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=["i32", "f32", "bf16"])
def test_merge_kernel_vs_oracle(na, nb, tile, dtype):
    rng = np.random.default_rng(na * 31 + nb)
    a = jnp.asarray(_sorted(rng, na, dtype))
    b = jnp.asarray(_sorted(rng, nb, dtype))
    out = merge_pallas(a, b, tile=tile)
    exp = ref.merge_ref(a, b)
    np.testing.assert_array_equal(
        np.asarray(out.astype(jnp.float32)), np.asarray(exp.astype(jnp.float32))
    )


@pytest.mark.parametrize("na,nb,tile", [(800, 600, 256), (1024, 1024, 128), (3000, 72, 512)])
def test_merge_kv_kernel_stability(na, nb, tile):
    rng = np.random.default_rng(7)
    ak = jnp.asarray(np.sort(rng.integers(0, 20, na)).astype(np.int32))
    bk = jnp.asarray(np.sort(rng.integers(0, 20, nb)).astype(np.int32))
    av = jnp.arange(na, dtype=jnp.float32)
    bv = 10_000 + jnp.arange(nb, dtype=jnp.float32)
    ko, vo = merge_kv_pallas(ak, av, bk, bv, tile=tile)
    rk, rv = ref.merge_kv_ref(ak, av, bk, bv)
    np.testing.assert_array_equal(np.asarray(ko), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(vo), np.asarray(rv))


def test_duplicate_heavy_inputs():
    """All-equal keys: rank arithmetic must not collide or drop."""
    a = jnp.full((700,), 3, jnp.int32)
    b = jnp.full((500,), 3, jnp.int32)
    out = merge_pallas(a, b, tile=128)
    np.testing.assert_array_equal(np.asarray(out), np.full(1200, 3))


def test_ops_sort_and_sort_kv():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal(3000).astype(np.float32))
    np.testing.assert_allclose(np.asarray(ops.sort(x, tile=512)), np.sort(np.asarray(x)))
    k = jnp.asarray(rng.integers(0, 8, 2048).astype(np.int32))
    v = jnp.arange(2048, dtype=jnp.int32)
    ks, vs = ops.sort_kv(k, v, tile=512)
    rks, rvs = ref.sort_kv_ref(k, v)
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(rks))
    np.testing.assert_array_equal(np.asarray(vs), np.asarray(rvs))


def test_ops_merge_small_fallback():
    a = jnp.array([1, 3], jnp.int32)
    b = jnp.array([2], jnp.int32)
    np.testing.assert_array_equal(np.asarray(ops.merge(a, b)), [1, 2, 3])
