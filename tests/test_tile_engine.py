"""Hierarchical two-level tile engine: bit-exactness + satellite coverage.

The property the whole PR rests on: for every kernel variant, the
hierarchical engine (level-2 sub-diagonal bisection + (S, S) leaf merge
matrices + O(T) gather apply) produces output **bit-identical** to the
single-level (T, T) merge-matrix engine — over fuzzed windows with
duplicates, payload keys tied with the sentinel (``+inf`` /
``iinfo.max``), ragged valid lengths, and non-divisible T/S combos.

Also covered: the flat sort rounds (padding hoisted out of the loop),
the (tile, leaf) autotune table, the env-overridable interpret default,
and the consumer routes (MoE dispatch, sampler, distributed sort).
"""

import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import batched as bat
from repro.core import merge_path as mp
from repro.kernels import ops, ref, tune
from repro.kernels.merge_path import (
    merge_batched_pallas,
    merge_batched_ragged_pallas,
    merge_kv_batched_ragged_pallas,
    merge_kv_pallas,
    merge_pallas,
)

I32MAX = np.iinfo(np.int32).max


def _eq(got, exp):
    np.testing.assert_array_equal(
        np.asarray(got).astype(np.float64), np.asarray(exp).astype(np.float64)
    )


def _fuzz_sorted(rng, n, dtype, sentinel_ties: bool):
    """Sorted 1-D data with heavy duplicates; optionally sentinel-valued
    payload tail (+inf / iinfo.max) — the classic pad-shadowing trap."""
    if np.dtype(dtype) == np.int32:
        x = np.sort(rng.integers(-8, 8, n)).astype(np.int32)
        if sentinel_ties and n >= 2:
            x[-(n // 4 or 1):] = I32MAX
    else:
        x = np.sort(rng.standard_normal(n)).astype(np.float32)
        if sentinel_ties and n >= 2:
            x[-(n // 4 or 1):] = np.inf
    return x


# ---------------------------------------------------------------------------
# Fuzzed bit-exactness: hier == matrix == oracle
# ---------------------------------------------------------------------------

# (seed, tile, leaf) — leaves chosen to hit S | T, S ∤ T, S == T, S > T
FUZZ_1D = [
    (s, t, l)
    for s, (t, l) in enumerate(
        [
            (64, 8), (64, 24), (128, 32), (128, 100), (128, 128),
            (192, 32), (192, 56), (256, 16), (256, 192), (96, 32),
            (128, 8), (256, 256), (160, 48), (64, 64), (256, 11),
        ]
    )
]


@pytest.mark.parametrize("seed,tile,leaf", FUZZ_1D)
@pytest.mark.parametrize("dtype", [np.int32, np.float32], ids=["i32", "f32"])
def test_fuzz_1d_hier_matrix_oracle(seed, tile, leaf, dtype):
    rng = np.random.default_rng(seed)
    na, nb = int(rng.integers(0, 1500)), int(rng.integers(0, 1500))
    ties = bool(rng.integers(0, 2))
    a = jnp.asarray(_fuzz_sorted(rng, na, dtype, ties))
    b = jnp.asarray(_fuzz_sorted(rng, nb, dtype, ties))
    h = merge_pallas(a, b, tile=tile, leaf=leaf, engine="hier")
    m = merge_pallas(a, b, tile=tile, leaf=leaf, engine="matrix")
    _eq(h, m)
    _eq(h, ref.merge_ref(a, b))


@pytest.mark.parametrize("seed,tile,leaf", [(0, 128, 32), (1, 128, 48), (2, 256, 17), (3, 64, 64)])
def test_fuzz_kv_sentinel_tied_keys(seed, tile, leaf):
    """Payload keys equal to the sentinel must keep their values through
    both engines (pads are excluded by index, never by comparison)."""
    rng = np.random.default_rng(100 + seed)
    na, nb = int(rng.integers(1, 1200)), int(rng.integers(1, 1200))
    ak = _fuzz_sorted(rng, na, np.int32, True)
    bk = _fuzz_sorted(rng, nb, np.int32, True)
    av = np.arange(na, dtype=np.float32)
    bv = 10_000 + np.arange(nb, dtype=np.float32)
    args = tuple(map(jnp.asarray, (ak, av, bk, bv)))
    kh, vh = merge_kv_pallas(*args, tile=tile, leaf=leaf, engine="hier")
    km, vm = merge_kv_pallas(*args, tile=tile, leaf=leaf, engine="matrix")
    _eq(kh, km)
    _eq(vh, vm)
    rk, rv = ref.merge_kv_ref(*args)
    _eq(kh, rk)
    _eq(vh, rv)


@pytest.mark.parametrize("seed,tile,leaf", [(0, 64, 16), (1, 128, 40), (2, 128, 128), (3, 96, 32)])
def test_fuzz_batched_hier_vs_matrix(seed, tile, leaf):
    rng = np.random.default_rng(200 + seed)
    bsz, n = int(rng.integers(1, 5)), int(rng.integers(2, 600))
    a = jnp.asarray(np.sort(rng.standard_normal((bsz, n)), axis=1).astype(np.float32))
    b = jnp.asarray(np.sort(rng.standard_normal((bsz, n)), axis=1).astype(np.float32))
    h = merge_batched_pallas(a, b, tile=tile, leaf=leaf, engine="hier")
    m = merge_batched_pallas(a, b, tile=tile, leaf=leaf, engine="matrix")
    _eq(h, m)
    _eq(h, bat.merge_batched(a, b))


@pytest.mark.parametrize("seed,tile,leaf", [(0, 64, 16), (1, 128, 24), (2, 128, 100), (3, 256, 32)])
def test_fuzz_ragged_hier_vs_matrix(seed, tile, leaf):
    """Ragged rows: full outputs (incl. the visible sentinel tails) must be
    bit-identical across engines AND to the fused core path."""
    rng = np.random.default_rng(300 + seed)
    bsz, n = int(rng.integers(1, 5)), int(rng.integers(2, 500))
    a = jnp.asarray(np.sort(rng.integers(-6, 6, (bsz, n)), axis=1).astype(np.int32))
    b = jnp.asarray(np.sort(rng.integers(-6, 6, (bsz, n)), axis=1).astype(np.int32))
    al = jnp.asarray(rng.integers(0, n + 1, bsz), jnp.int32)
    bl = jnp.asarray(rng.integers(0, n + 1, bsz), jnp.int32)
    h = merge_batched_ragged_pallas(a, b, al, bl, tile=tile, leaf=leaf, engine="hier")
    m = merge_batched_ragged_pallas(a, b, al, bl, tile=tile, leaf=leaf, engine="matrix")
    _eq(h, m)
    _eq(h, bat.merge_batched_ragged(a, b, al, bl))


@pytest.mark.parametrize("seed,tile,leaf", [(0, 64, 24), (1, 128, 32), (2, 128, 56)])
def test_fuzz_ragged_kv_sentinel_ties(seed, tile, leaf):
    """Ragged kv with sentinel-tied payload keys: valid +inf/iinfo.max keys
    keep their values; sentinel-pad tails carry zero values, identically
    across engines and vs the core ragged kv merge."""
    rng = np.random.default_rng(400 + seed)
    bsz, n = int(rng.integers(1, 4)), int(rng.integers(4, 400))
    ak = np.sort(rng.integers(-5, 5, (bsz, n)), axis=1).astype(np.int32)
    bk = np.sort(rng.integers(-5, 5, (bsz, n)), axis=1).astype(np.int32)
    ak[:, -max(1, n // 5):] = I32MAX  # real payloads tied with the pad sentinel
    bk[:, -max(1, n // 5):] = I32MAX
    av = rng.standard_normal((bsz, n)).astype(np.float32)
    bv = rng.standard_normal((bsz, n)).astype(np.float32)
    al = jnp.asarray(rng.integers(0, n + 1, bsz), jnp.int32)
    bl = jnp.asarray(rng.integers(0, n + 1, bsz), jnp.int32)
    args = tuple(map(jnp.asarray, (ak, av, bk, bv))) + (al, bl)
    kh, vh = merge_kv_batched_ragged_pallas(*args, tile=tile, leaf=leaf, engine="hier")
    km, vm = merge_kv_batched_ragged_pallas(*args, tile=tile, leaf=leaf, engine="matrix")
    _eq(kh, km)
    _eq(vh, vm)
    rk, rv = bat.merge_kv_batched_ragged(*args)
    _eq(kh, rk)
    _eq(vh, rv)


# ---------------------------------------------------------------------------
# Flat sort rounds (hoisted padding)
# ---------------------------------------------------------------------------


def test_sort_flat_rounds_vs_numpy():
    rng = np.random.default_rng(7)
    for n in (1, 2, 777, 3000):
        x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        _eq(ops.sort(x, tile=64), np.sort(np.asarray(x)))


def test_sort_rejects_non_pow2_tile():
    """Flat sort rounds need tile | 2*width — an explicit non-pow2 tile is
    an error, not a silent rewrite (merge wrappers still honor any tile)."""
    x = jnp.arange(512, dtype=jnp.float32)
    with pytest.raises(ValueError, match="power of two"):
        ops.sort(x, tile=200)
    with pytest.raises(ValueError, match="power of two"):
        ops.sort_kv_batched(x[None, :], x[None, :].astype(jnp.int32), tile=96)


def test_sort_matrix_engine_equivalence():
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.integers(-50, 50, 600).astype(np.int32))
    _eq(ops.sort(x, tile=64, engine="matrix"), ops.sort(x, tile=64, engine="hier"))


def test_sort_kv_flat_rounds_stable():
    rng = np.random.default_rng(9)
    k = jnp.asarray(rng.integers(0, 6, 2048).astype(np.int32))
    v = jnp.arange(2048, dtype=jnp.int32)
    ks, vs = ops.sort_kv(k, v, tile=128)
    rk, rv = ref.sort_kv_ref(k, v)
    _eq(ks, rk)
    _eq(vs, rv)


def test_sort_batched_rows_never_mix():
    rng = np.random.default_rng(10)
    x = jnp.asarray(rng.standard_normal((6, 700)).astype(np.float32))
    _eq(ops.sort_batched(x, tile=128), np.sort(np.asarray(x), axis=1))


def test_sort_kv_batched_is_stable_argsort():
    rng = np.random.default_rng(11)
    k = jnp.asarray(rng.integers(0, 9, (4, 900)).astype(np.int32))
    v = jnp.broadcast_to(jnp.arange(900, dtype=jnp.int32)[None, :], (4, 900))
    ks, vs = ops.sort_kv_batched(k, v, tile=128)
    _eq(ks, np.sort(np.asarray(k), axis=1))
    _eq(vs, np.argsort(np.asarray(k), axis=1, kind="stable"))


def test_ops_topk_matches_core_and_lax():
    rng = np.random.default_rng(12)
    x = jnp.asarray(rng.standard_normal((3, 1500)).astype(np.float32))
    vals, idx = ops.topk_batched(x, 25, tile=128)
    lv, li = jax.lax.top_k(x, 25)
    _eq(vals, lv)
    _eq(idx, li)
    # int rows containing iinfo.min (flip_desc exactness)
    xi = jnp.asarray(rng.integers(-100, 100, (2, 640)).astype(np.int32))
    xi = xi.at[0, 0].set(np.iinfo(np.int32).min)
    vi, ii = ops.topk_batched(xi, 10, tile=64)
    cv, ci = bat.topk_batched(xi, 10)
    _eq(vi, cv)
    _eq(ii, ci)


def test_ops_topk_ragged_matches_core():
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.standard_normal((4, 800)).astype(np.float32))
    lens = jnp.asarray([800, 500, 3, 0], jnp.int32)
    vals, idx = ops.topk_batched_ragged(x, 20, lens, tile=128)
    cv, ci = bat.topk_batched_ragged(x, 20, lens)
    _eq(vals, cv)
    _eq(idx, ci)


# ---------------------------------------------------------------------------
# Autotune table
# ---------------------------------------------------------------------------


def test_tune_pick_sane():
    for n in (16, 1000, 1 << 12, 1 << 15, 1 << 20):
        for dt in (jnp.float32, jnp.int32, jnp.bfloat16):
            tile, leaf = tune.pick(n, dt)
            assert tile & (tile - 1) == 0, (n, dt, tile)
            assert 1 <= leaf <= tile
    # tiny problems never get a tile wider than the (pow2-rounded) problem
    tile, _ = tune.pick(16, jnp.float32)
    assert tile <= 128


def test_tune_autotune_updates_table():
    best = tune.autotune(512, jnp.float32, tiles=(128, 256), leaves=(16, 32), iters=1)
    assert best[0] in (128, 256) and best[1] in (16, 32)
    assert tune._TABLE[("f", tune._bucket(512))] == best
    # restore the shipped entry so other tests see the defaults
    tune._TABLE.clear()
    tune._TABLE.update(tune.DEFAULT_TABLE)


# ---------------------------------------------------------------------------
# Interpret default (env-overridable, no call-site edits)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("env,expected", [("0", "False"), ("false", "False"), ("1", "True"), (None, "True")])
def test_interpret_env_default(env, expected):
    code = "from repro.kernels import ops; print(ops.DEFAULT_INTERPRET)"
    e = dict(os.environ)
    e["PYTHONPATH"] = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    e.pop("REPRO_PALLAS_INTERPRET", None)
    if env is not None:
        e["REPRO_PALLAS_INTERPRET"] = env
    out = subprocess.run(
        [sys.executable, "-c", code], env=e, capture_output=True, text=True, check=True
    )
    assert out.stdout.strip() == expected


# ---------------------------------------------------------------------------
# Consumer routes
# ---------------------------------------------------------------------------


def test_moe_positions_pallas_backend_parity():
    from repro.models.moe import _positions_merge_path_batched

    rng = np.random.default_rng(14)
    fe = jnp.asarray(rng.integers(0, 8, (3, 640)).astype(np.int32))
    _eq(
        _positions_merge_path_batched(fe, 8, None, "pallas"),
        _positions_merge_path_batched(fe, 8),
    )
    sl = jnp.asarray([640, 200, 0], jnp.int32)
    _eq(
        _positions_merge_path_batched(fe, 8, sl, "pallas"),
        _positions_merge_path_batched(fe, 8, sl),
    )


def test_sampler_pallas_backend_parity():
    from repro.serving.sampler import topk_sample, topp_sample

    rng = np.random.default_rng(15)
    logits = jnp.asarray(rng.standard_normal((3, 1024)).astype(np.float32))
    key = jax.random.key(21)
    _eq(topk_sample(logits, key, backend="pallas", tile=128), topk_sample(logits, key))
    vl = jnp.asarray([1024, 700, 40], jnp.int32)
    _eq(
        topk_sample(logits, key, vocab_lens=vl, backend="pallas", tile=128),
        topk_sample(logits, key, vocab_lens=vl),
    )
    _eq(topp_sample(logits, key, backend="pallas", tile=128), topp_sample(logits, key))


def test_distributed_sort_pallas_local():
    from repro.core import distributed_sort

    rng = np.random.default_rng(16)
    x = jnp.asarray(rng.standard_normal(512).astype(np.float32))
    out_c, cnt_c, ovf_c = distributed_sort(x)
    out_p, cnt_p, ovf_p = distributed_sort(x, local_sort="pallas")
    _eq(out_p, out_c)
    _eq(cnt_p, cnt_c)
    assert not bool(ovf_p)
