"""Batched & k-way Merge Path subsystem (`repro.core.batched` + the 2-D
grid Pallas kernels).  Pure pytest — no hypothesis, so this file is the
tier-1 coverage for the batched API in offline containers."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    merge,
    merge_kv,
    merge_batched,
    merge_kv_batched,
    merge_k,
    merge_k_kv,
    merge_sort_batched,
    merge_sort_k,
    merge_sort_kv_batched,
    searchsorted_batched,
    stable_argsort_batched,
    topk_batched,
)
from repro.kernels import merge_batched_pallas, merge_kv_batched_pallas
from repro.kernels import ops


def sorted_rows(rng, b, n, lo=-1000, hi=1000, dtype=np.int32):
    return np.sort(rng.integers(lo, hi, (b, n)), axis=1).astype(dtype)


# --- fused batched primitives ------------------------------------------------

def test_searchsorted_batched_matches_numpy():
    rng = np.random.default_rng(0)
    s = sorted_rows(rng, 6, 50)
    q = rng.integers(-1100, 1100, (6, 33)).astype(np.int32)
    for side in ("left", "right"):
        got = np.asarray(searchsorted_batched(jnp.array(s), jnp.array(q), side))
        ref = np.stack([np.searchsorted(s[i], q[i], side=side) for i in range(6)])
        np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("na,nb", [(40, 40), (100, 7), (7, 100), (1, 1)])
def test_merge_batched_matches_vmapped_merge(na, nb):
    """Uneven |A| != |B| included: batched == vmapped pairwise, bit-exact."""
    rng = np.random.default_rng(na * 1000 + nb)
    a = sorted_rows(rng, 5, na)
    b = sorted_rows(rng, 5, nb)
    out = np.asarray(merge_batched(jnp.array(a), jnp.array(b)))
    ref = np.asarray(jax.vmap(merge)(jnp.array(a), jnp.array(b)))
    np.testing.assert_array_equal(out, ref)


def test_merge_batched_empty_rows():
    """Zero-width sides: (B, 0) merges are the identity on the other side."""
    rng = np.random.default_rng(1)
    a = sorted_rows(rng, 4, 9)
    e = jnp.zeros((4, 0), jnp.int32)
    np.testing.assert_array_equal(np.asarray(merge_batched(jnp.array(a), e)), a)
    np.testing.assert_array_equal(np.asarray(merge_batched(e, jnp.array(a))), a)
    both = merge_batched(e, e)
    assert both.shape == (4, 0)


def test_merge_kv_batched_stability_a_priority():
    """Duplicate keys: ties take A first and preserve in-array order, per row."""
    ak = jnp.array([[1, 1, 2], [5, 5, 5]], jnp.int32)
    av = jnp.array([[10, 11, 12], [10, 11, 12]], jnp.int32)
    bk = jnp.array([[1, 2, 2], [5, 5, 6]], jnp.int32)
    bv = jnp.array([[20, 21, 22], [20, 21, 22]], jnp.int32)
    ko, vo = merge_kv_batched(ak, av, bk, bv)
    np.testing.assert_array_equal(np.asarray(ko), [[1, 1, 1, 2, 2, 2], [5, 5, 5, 5, 5, 6]])
    np.testing.assert_array_equal(np.asarray(vo), [[10, 11, 20, 12, 21, 22], [10, 11, 12, 20, 21, 22]])


def test_merge_sort_batched_matches_jnp_sort():
    rng = np.random.default_rng(2)
    x = rng.standard_normal((8, 321)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(merge_sort_batched(jnp.array(x))), np.asarray(jnp.sort(jnp.array(x), axis=1))
    )


def test_merge_sort_kv_batched_stable():
    rng = np.random.default_rng(3)
    k = rng.integers(0, 5, (4, 130)).astype(np.int32)
    v = np.broadcast_to(np.arange(130, dtype=np.int32), (4, 130)).copy()
    ks, vs = merge_sort_kv_batched(jnp.array(k), jnp.array(v))
    for r in range(4):
        order = np.argsort(k[r], kind="stable")
        np.testing.assert_array_equal(np.asarray(ks)[r], k[r][order])
        np.testing.assert_array_equal(np.asarray(vs)[r], v[r][order])


def test_stable_argsort_and_topk_batched():
    rng = np.random.default_rng(4)
    x = rng.standard_normal((6, 200)).astype(np.float32)
    perm = np.asarray(stable_argsort_batched(jnp.array(x)))
    for r in range(6):
        np.testing.assert_array_equal(perm[r], np.argsort(x[r], kind="stable"))
    v, i = topk_batched(jnp.array(x), 17)
    rv, ri = jax.lax.top_k(jnp.array(x), 17)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


# --- k-way tournament merges -------------------------------------------------

def test_merge_k_identity_k1():
    x = np.sort(np.random.default_rng(5).integers(-50, 50, 13)).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(merge_k([jnp.array(x)])), x)
    np.testing.assert_array_equal(np.asarray(merge_k(jnp.array(x)[None, :])), x)


@pytest.mark.parametrize("k", [2, 3, 5, 8])
def test_merge_k_stacked_matches_sort(k):
    """k > 2 tournaments (incl. non-power-of-two k) agree with the oracle."""
    rng = np.random.default_rng(k)
    runs = np.sort(rng.integers(-100, 100, (k, 16)), axis=1).astype(np.int32)
    out = np.asarray(merge_k(jnp.array(runs)))
    np.testing.assert_array_equal(out, np.sort(runs.reshape(-1), kind="stable"))


def test_merge_k_ragged_runs():
    rng = np.random.default_rng(6)
    runs = [np.sort(rng.integers(-40, 40, n)).astype(np.int32) for n in (5, 0, 12, 3, 9)]
    out = np.asarray(merge_k([jnp.array(r) for r in runs]))
    np.testing.assert_array_equal(out, np.sort(np.concatenate(runs)))


def test_merge_k_kv_stable_across_runs():
    """Ties resolve toward the lower-indexed run, preserving in-run order."""
    rng = np.random.default_rng(7)
    kk = np.sort(rng.integers(0, 6, (4, 8)), axis=1).astype(np.int32)
    vv = np.arange(32, dtype=np.int32).reshape(4, 8)
    mk, mv = merge_k_kv(jnp.array(kk), jnp.array(vv))
    order = np.argsort(kk.reshape(-1), kind="stable")  # run-major flatten == run priority
    np.testing.assert_array_equal(np.asarray(mk), kk.reshape(-1)[order])
    np.testing.assert_array_equal(np.asarray(mv), vv.reshape(-1)[order])


@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_merge_sort_k_matches_jnp_sort(k):
    rng = np.random.default_rng(10 + k)
    x = rng.standard_normal(777).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(merge_sort_k(jnp.array(x), k)), np.asarray(jnp.sort(jnp.array(x)))
    )


def test_merge_sort_k_rejects_non_power_of_two():
    with pytest.raises(ValueError):
        merge_sort_k(jnp.arange(8, dtype=jnp.int32), 3)


# --- 2-D grid Pallas kernels -------------------------------------------------

@pytest.mark.parametrize("na,nb,tile", [(300, 212, 128), (128, 128, 128), (100, 30, 64)])
def test_merge_batched_pallas_matches_vmapped_merge(na, nb, tile):
    """Non-divisible tile sizes included: (na+nb) % tile != 0 cases."""
    rng = np.random.default_rng(na + nb + tile)
    a = np.sort(rng.standard_normal((3, na)), axis=1).astype(np.float32)
    b = np.sort(rng.standard_normal((3, nb)), axis=1).astype(np.float32)
    out = np.asarray(merge_batched_pallas(jnp.array(a), jnp.array(b), tile=tile))
    ref = np.asarray(jax.vmap(merge)(jnp.array(a), jnp.array(b)))
    np.testing.assert_array_equal(out, ref)


def test_merge_kv_batched_pallas_matches_vmapped_merge_kv():
    rng = np.random.default_rng(8)
    ak = sorted_rows(rng, 3, 260)
    bk = sorted_rows(rng, 3, 190)
    av = rng.integers(0, 10**6, (3, 260)).astype(np.int32)
    bv = rng.integers(0, 10**6, (3, 190)).astype(np.int32)
    ko, vo = merge_kv_batched_pallas(
        jnp.array(ak), jnp.array(av), jnp.array(bk), jnp.array(bv), tile=128
    )
    rk, rv = jax.vmap(merge_kv)(jnp.array(ak), jnp.array(av), jnp.array(bk), jnp.array(bv))
    np.testing.assert_array_equal(np.asarray(ko), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(vo), np.asarray(rv))


def test_ops_merge_batched_both_dispatch_paths():
    rng = np.random.default_rng(9)
    a = np.sort(rng.standard_normal((4, 100)), axis=1).astype(np.float32)
    b = np.sort(rng.standard_normal((4, 80)), axis=1).astype(np.float32)
    ref = np.asarray(jax.vmap(merge)(jnp.array(a), jnp.array(b)))
    # small path (fused pure-JAX) and kernel path must agree bit-exactly
    np.testing.assert_array_equal(
        np.asarray(ops.merge_batched(jnp.array(a), jnp.array(b), tile=512)), ref
    )
    np.testing.assert_array_equal(
        np.asarray(ops.merge_batched(jnp.array(a), jnp.array(b), tile=64)), ref
    )


def test_ops_sort_wide_rounds_on_batched_kernel():
    rng = np.random.default_rng(11)
    x = rng.standard_normal(2048).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(ops.sort(jnp.array(x), tile=256)), np.asarray(jnp.sort(jnp.array(x)))
    )
    k = rng.integers(0, 7, 2048).astype(np.int32)
    v = np.arange(2048, dtype=np.int32)
    ks, vs = ops.sort_kv(jnp.array(k), jnp.array(v), tile=256)
    order = np.argsort(k, kind="stable")
    np.testing.assert_array_equal(np.asarray(ks), k[order])
    np.testing.assert_array_equal(np.asarray(vs), v[order])


# --- acceptance: the issue's (64, 4096) case --------------------------------

def test_acceptance_64x4096_bit_exact():
    """merge_batched on a (64, 4096)+(64, 4096) batch == vmapped core.merge,
    bit-exact (stable, A-priority), on both the fused core path and the
    2-D-grid Pallas kernel."""
    rng = np.random.default_rng(64)
    a = np.sort(rng.standard_normal((64, 4096)), axis=1).astype(np.float32)
    b = np.sort(rng.standard_normal((64, 4096)), axis=1).astype(np.float32)
    ref = np.asarray(jax.vmap(merge)(jnp.array(a), jnp.array(b)))
    np.testing.assert_array_equal(np.asarray(merge_batched(jnp.array(a), jnp.array(b))), ref)
    out = np.asarray(merge_batched_pallas(jnp.array(a), jnp.array(b), tile=1024))
    np.testing.assert_array_equal(out, ref)
