"""The static checker checked: every shipped rule must fire on a known-bad
fixture, and the real tree must pass clean.

Engine 1 (abstract kernel analysis, ``repro.analysis``): rules are plain
functions over explicit parameters, so the known-bad fixtures are just
hostile configs/contracts — a tile that overflows the VMEM model, a
padding model with the sentinel tail removed, a values-carrying contract
claiming an unmasked rank path.

Engine 2 (AST lint, ``tools/lint_rules.py``): the fixtures are source
snippets — a literal ``interpret=True`` call site, ``-x`` on sort keys,
raw ``iinfo`` sentinels, a loop-over-pairs kernel launch, an untested
``custom_vjp``.

Bench gate (``tools/bench_diff.py``): synthetic snapshot payloads with a
>20% anchor regression, plus the graceful missing-baseline paths.
"""

import json
import sys
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parent.parent
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))

from tools import bench_diff, lint_rules  # noqa: E402

from repro import analysis  # noqa: E402
from repro.analysis import (  # noqa: E402
    LatticeConfig,
    Violation,
    block_divisibility_violations,
    check_kernels,
    completeness_violations,
    prefetch_violations,
    registered_contracts,
    rejection_violations,
    sentinel_violations,
    vmem_bytes,
    vmem_violations,
)

# importing the kernel modules populates the registry
import repro.kernels.ops  # noqa: E402,F401
import repro.kernels.ssm_scan  # noqa: E402,F401

CONTRACTS = registered_contracts()


# ---------------------------------------------------------------------------
# Engine 1: clean tree + every rule fires on a known-bad fixture
# ---------------------------------------------------------------------------


def test_clean_tree_passes_abstract_analysis():
    # the repo's own contracts must prove out on the (fast) lattice —
    # pure eval_shape tracing, zero device kernel launches
    violations = check_kernels(fast=True)
    assert violations == [], "\n".join(str(v) for v in violations)


def test_registry_covers_all_public_entry_points():
    # 13 ops wrappers + the fused SSM scan
    assert len(CONTRACTS) == 14
    assert completeness_violations(CONTRACTS) == []


def test_a000_fires_on_missing_annotation():
    vs = completeness_violations(contracts={})
    assert vs and all(v.rule == "A000" for v in vs)
    assert any(v.kernel == "merge" for v in vs)
    assert any(v.kernel == "ssm_scan_pallas" for v in vs)


def test_a002_fires_on_non_pow2_sort_tile():
    vs = block_divisibility_violations(CONTRACTS["sort"], LatticeConfig(n=4096, tile=384))
    assert any(v.rule == "A002" and "power of two" in v.message for v in vs)


def test_a002_fires_on_silently_accepted_bad_tile():
    # ops.merge takes any tile, so a contract that CLAIMS pow2 rejection
    # for it must be caught: eval_shape succeeds where a ValueError was due
    bad = CONTRACTS["merge"].with_(pow2_tile=True)
    vs = rejection_violations(bad, bad_tile=96)
    assert any(v.rule == "A002" and "silently accepted" in v.message for v in vs)


def test_a002_clean_on_real_sort_rejection():
    # the real sort wrapper raises ValueError on a non-pow2 tile
    assert rejection_violations(CONTRACTS["sort"], bad_tile=96) == []


def test_a003_fires_when_sentinel_padding_removed():
    # `_prepare` pads each buffer with `tile` sentinels; model a kernel
    # that forgot them and the window reads run off the end
    cfg = LatticeConfig(n=4096, tile=512)
    vs = prefetch_violations(CONTRACTS["merge"], cfg, pad_elems=0)
    assert any(v.rule == "A003" for v in vs)
    # sort rounds over the flat buffer hit the same wall
    vs = prefetch_violations(CONTRACTS["sort"], cfg, pad_elems=0)
    assert any(v.rule == "A003" for v in vs)
    # with the real tile-sized padding both are in bounds
    assert prefetch_violations(CONTRACTS["merge"], cfg) == []
    assert prefetch_violations(CONTRACTS["sort"], cfg) == []


def test_a004_fires_on_unmasked_values_contract():
    bad = CONTRACTS["merge_kv"].with_(masked_ranks=False)
    vs = sentinel_violations(bad)
    assert any(v.rule == "A004" and "UNMASKED" in v.message for v in vs)
    # an unmasked keys-only contract without justification also fails
    bad = CONTRACTS["merge"].with_(tie_safe=None)
    assert any(v.rule == "A004" for v in sentinel_violations(bad))
    # ...and the real contracts are fine
    for c in CONTRACTS.values():
        assert sentinel_violations(c) == []


def test_a005_fires_on_vmem_overflowing_config():
    # a 64Ki-wide matrix-engine tile models a (T, T) merge matrix of
    # multiple GB — far past any device budget
    cfg = LatticeConfig(tile=65536, engine="matrix")
    vs = vmem_violations(CONTRACTS["merge"], cfg)
    assert vs and all(v.rule == "A005" for v in vs)
    # and against a custom (tiny) budget table even the default fits not
    vs = vmem_violations(CONTRACTS["merge"], LatticeConfig(), budgets={"tiny": 1024})
    assert any(v.rule == "A005" and "tiny" in v.message for v in vs)


def test_a005_vmem_model_is_monotone_and_fits_defaults():
    m = CONTRACTS["merge"]
    small = vmem_bytes(m, LatticeConfig(tile=128, leaf=8))
    big = vmem_bytes(m, LatticeConfig(tile=1024, leaf=32))
    assert 0 < small < big
    # the SSM backward slab dominates its forward
    s = CONTRACTS["ssm_scan_pallas"]
    fwd_only = s.with_(differentiable=False)
    assert vmem_bytes(fwd_only, LatticeConfig()) < vmem_bytes(s, LatticeConfig())


def test_violation_formatting():
    v = Violation("A005", "merge", "tile=65536", "too big")
    assert "A005" in str(v) and "merge" in str(v)


# ---------------------------------------------------------------------------
# Engine 2: AST lint rules
# ---------------------------------------------------------------------------


def _lint(src, path="src/repro/kernels/fixture.py", owners=None):
    return lint_rules.lint_source(src, path, collect_vjp_owners=owners)


def test_l001_fires_on_literal_interpret():
    vs = _lint("merge_pallas(a, b, interpret=True)\n")
    assert any(v.rule == "L001" for v in vs)
    # routed through the resolver: clean
    assert not any(
        v.rule == "L001" for v in _lint("merge_pallas(a, b, interpret=_interp(flag))\n")
    )


def test_l002_fires_on_negated_sort_keys():
    vs = _lint("out = ops.sort(-keys)\n")
    assert any(v.rule == "L002" and "flip_desc" in v.message for v in vs)
    # literal negative numbers are not key negations
    assert not any(v.rule == "L002" for v in _lint("out = ops.topk_batched(x, -1)\n"))
    # the sanctioned bit-flip form is clean
    assert not any(v.rule == "L002" for v in _lint("out = ops.sort(~keys)\n"))


def test_l003_fires_on_raw_sentinels_outside_helper():
    for snippet in (
        "pad = jnp.iinfo(jnp.int32).max\n",
        "pad = np.finfo(x.dtype).max\n",
        "pad = jnp.inf\n",
    ):
        vs = _lint(snippet, path="src/repro/serving/fixture.py")
        assert any(v.rule == "L003" for v in vs), snippet
    # the one sanctioned helper module is exempt
    vs = _lint("pad = jnp.iinfo(jnp.int32).max\n", path="src/repro/core/merge_path.py")
    assert not any(v.rule == "L003" for v in vs)


def test_l004_fires_on_loop_over_pairs_kernel_launch():
    snippet = (
        "def rounds(pairs):\n"
        "    for a, b in pairs:\n"
        "        out = merge_pallas(a, b, tile=512)\n"
    )
    vs = _lint(snippet, path="src/repro/kernels/fixture.py")
    assert any(v.rule == "L004" for v in vs)
    # the same loop outside kernels/ (benchmarks, tests) is fine
    assert not any(
        v.rule == "L004" for v in _lint(snippet, path="src/repro/serving/fixture.py")
    )


def test_l005_fires_on_untested_custom_vjp():
    snippet = (
        "def mystery_op(x):\n"
        "    @jax.custom_vjp\n"
        "    def f(xx):\n"
        "        return xx\n"
        "    return f(x)\n"
    )
    owners = []
    _lint(snippet, owners=owners)
    assert owners == ["mystery_op"]
    vs = lint_rules.vjp_pairing_violations(
        [(o, "src/repro/kernels/fixture.py", 1) for o in owners],
        grad_corpus="jax.grad of something_else",
    )
    assert any(v.rule == "L005" for v in vs)
    # a corpus that exercises the (public) name passes; private
    # underscored forwards are matched through their public name
    assert lint_rules.vjp_pairing_violations(
        [("_mystery_op", "f.py", 1)], "grad check for mystery_op"
    ) == []


def test_l006_fires_on_broad_except_around_launch():
    snippet = (
        "def dispatch(a, b):\n"
        "    try:\n"
        "        out = merge_pallas(a, b, tile=512)\n"
        "    except Exception:\n"
        "        out = merge_core(a, b)\n"
        "    return out\n"
    )
    vs = _lint(snippet, path="src/repro/kernels/fixture.py")
    assert any(v.rule == "L006" for v in vs)
    # bare except is just as forbidden
    bare = snippet.replace("except Exception:", "except:")
    assert any(v.rule == "L006" for v in _lint(bare, path="src/repro/kernels/fixture.py"))


def test_l006_allows_guard_layer_and_narrow_catches():
    snippet = (
        "def dispatch(a, b):\n"
        "    try:\n"
        "        out = merge_pallas(a, b, tile=512)\n"
        "    except Exception:\n"
        "        out = merge_core(a, b)\n"
        "    return out\n"
    )
    # the one sanctioned file: the guarded dispatch loop itself
    assert not any(
        v.rule == "L006"
        for v in _lint(snippet, path="src/repro/runtime/resilience.py")
    )
    # a narrow except (specific exception type) is fine anywhere
    narrow = snippet.replace("except Exception:", "except ValueError:")
    assert not any(
        v.rule == "L006" for v in _lint(narrow, path="src/repro/kernels/fixture.py")
    )
    # broad except around a non-launch body is not this rule's business
    no_launch = snippet.replace("merge_pallas(a, b, tile=512)", "merge_core(a, b)")
    assert not any(
        v.rule == "L006" for v in _lint(no_launch, path="src/repro/kernels/fixture.py")
    )


def test_lint_suppression_comment():
    vs = _lint("merge_pallas(a, b, interpret=True)  # lint: ok\n")
    assert vs == []
    vs = _lint("merge_pallas(a, b, interpret=True)  # lint: ok(L001)\n")
    assert vs == []
    # suppressing a DIFFERENT rule does not silence L001
    vs = _lint("merge_pallas(a, b, interpret=True)  # lint: ok(L004)\n")
    assert any(v.rule == "L001" for v in vs)


def test_lint_clean_tree():
    vs = lint_rules.lint_tree(REPO_ROOT)
    assert vs == [], "\n".join(str(v) for v in vs)


# ---------------------------------------------------------------------------
# Bench-diff perf gate
# ---------------------------------------------------------------------------


def _payload(us_spm=2800.0, us_batched=2500.0, bytes_dev=2984, smoke=True):
    return {
        "smoke": smoke,
        "rows": [
            {"name": "merge_throughput/pallas_spm_tile512/n=32768",
             "us_per_call": us_spm, "derived": "11 Melem/s"},
            {"name": "batched_merge/batched_pallas_2d_grid/B=32/n=512",
             "us_per_call": us_batched, "derived": "6 Melem/s"},
            {"name": "distributed/merge_window_n4096_p8",
             "us_per_call": 9e6,  # wall-clock is subprocess noise, not gated
             "derived": f"bytes/device={bytes_dev} total_bytes=16384"},
        ],
    }


def test_bench_diff_fires_on_time_regression():
    regs, _ = bench_diff.diff(_payload(), _payload(us_spm=2800 * 1.5))
    assert len(regs) == 1 and "pallas_spm_tile512" in regs[0]


def test_bench_diff_fires_on_bytes_regression():
    regs, _ = bench_diff.diff(_payload(), _payload(bytes_dev=4000))
    assert len(regs) == 1 and "bytes/device" in regs[0]


def test_bench_diff_tolerates_noise_and_improvement():
    regs, _ = bench_diff.diff(_payload(), _payload(us_spm=2800 * 1.15))
    assert regs == []
    # the distributed row's wall-clock is ignored entirely — only bytes gate
    regs, _ = bench_diff.diff(_payload(), _payload(us_batched=1000.0))
    assert regs == []


def test_bench_diff_skips_mismatched_smoke_flags():
    regs, notes = bench_diff.diff(_payload(), _payload(us_spm=9999.0, smoke=False))
    assert regs == [] and any("smoke" in n for n in notes)


def test_bench_diff_missing_baseline_is_graceful(tmp_path):
    assert bench_diff.check(tmp_path) == 0  # zero snapshots
    (tmp_path / "BENCH_1.json").write_text(json.dumps(_payload()))
    assert bench_diff.check(tmp_path) == 0  # one snapshot
    # an anchor missing on one side is skipped, not failed
    cur = _payload()
    cur["rows"] = cur["rows"][:1]
    (tmp_path / "BENCH_2.json").write_text(json.dumps(cur))
    assert bench_diff.check(tmp_path) == 0


def test_bench_diff_check_fails_on_regressed_snapshot(tmp_path):
    (tmp_path / "BENCH_1.json").write_text(json.dumps(_payload()))
    (tmp_path / "BENCH_2.json").write_text(json.dumps(_payload(us_spm=9000.0)))
    assert bench_diff.check(tmp_path) == 1


def test_bench_diff_next_name(tmp_path):
    assert bench_diff.next_name(tmp_path) == "BENCH_1.json"
    (tmp_path / "BENCH_3.json").write_text("{}")
    (tmp_path / "BENCH_10.json").write_text("{}")
    assert bench_diff.next_name(tmp_path) == "BENCH_11.json"
    # the repo itself has snapshots, so the derived name advances them
    n = int(bench_diff.next_name(REPO_ROOT).split("_")[1].split(".")[0])
    assert n >= 6
