"""Unit tests for sharding rules and the roofline HLO parser (no mesh,
no heavy compiles)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import TrainConfig, get_config
from repro.launch import roofline as rl
from repro.parallel.sharding import (
    MeshRules,
    make_rules,
    param_pspec_tree,
    sanitize_spec,
)


def _fake_mesh(shape=(2, 2), axes=("data", "model")):
    devs = np.array(jax.devices() * (int(np.prod(shape)) // len(jax.devices()) + 1))
    return Mesh(devs[: int(np.prod(shape))].reshape(shape), axes)


# --- param rules -------------------------------------------------------------

def test_param_pspec_rules_cover_all_leaves():
    rules = MeshRules()
    for arch in ("tinyllama-1.1b", "moonshot-v1-16b-a3b", "falcon-mamba-7b",
                 "whisper-large-v3", "paligemma-3b", "hymba-1.5b"):
        cfg = get_config(arch).reduced()
        from repro.models import abstract_params

        params = abstract_params(cfg)
        specs = param_pspec_tree(params, rules)
        leaves_p = jax.tree.leaves(params)
        leaves_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(leaves_p) == len(leaves_s)
        # matrices (ndim >= 2, non-norm) should be sharded on some dim
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        spec_flat = jax.tree_util.tree_flatten_with_path(specs)[0]
        n_sharded = sum(
            1 for (_, spec) in spec_flat
            if isinstance(spec, P) and any(e is not None for e in tuple(spec))
        )
        assert n_sharded > 0


def test_moe_expert_leading_dim_tensor_sharded():
    cfg = get_config("moonshot-v1-16b-a3b").reduced()
    from repro.models import abstract_params

    params = abstract_params(cfg)
    specs = param_pspec_tree(params, MeshRules())
    wi_spec = specs["layers"]["moe"]["wi"]
    # stacked layer dim, then (E, d, ff): experts on model, d on fsdp
    assert tuple(wi_spec) == (None, "model", "data", None)


def test_make_rules_multipod_and_fsdp_over_pod():
    mesh = _fake_mesh((1, 2, 2), ("pod", "data", "model"))
    r1 = make_rules(mesh)
    assert r1.batch == ("pod", "data") and r1.fsdp == ("data",)
    r2 = make_rules(mesh, fsdp_over_pod=True)
    assert r2.fsdp == ("pod", "data")
    r3 = make_rules(mesh, context_parallel=True)
    assert r3.context == ("model",)


def test_sanitize_spec_drops_indivisible():
    mesh = _fake_mesh((1, 4), ("data", "model"))
    spec = P("model", "data")
    out = sanitize_spec(spec, (32001, 1600), mesh)
    assert tuple(out) == (None, "data")  # 32001 % 4 != 0 -> replicated
    out2 = sanitize_spec(spec, (32000, 1600), mesh)
    assert tuple(out2) == ("model", "data")
    # tuple axes
    out3 = sanitize_spec(P(("data", "model")), (6,), mesh)
    assert tuple(out3) == (None,)


# --- roofline parser ---------------------------------------------------------

FAKE_HLO = """
  %ag = bf16[16,4096,2048]{2,1,0} all-gather(%x), channel_id=1, dimensions={2}
  %ar = f32[1024,512]{1,0} all-reduce(%y), to_apply=%add.1
  %arp = f32[1024,512]{1,0} all-reduce(%y2), to_apply=%add.2.clone_promoted
  %rs = (f32[64,64]{1,0}, f32[64,64]{1,0}) reduce-scatter(%a, %b), dimensions={0}
  %cp = bf16[8]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %a2a = s32[256,4]{1,0} all-to-all(%w), dimensions={0}
  %ars = f32[2,2]{1,0} all-reduce-start(%q), to_apply=%add.3
  %ard = f32[2,2]{1,0} all-reduce-done(%ars)
"""


def test_collective_parser_kinds_and_bytes():
    c = rl.collective_bytes(FAKE_HLO)
    # all-gather: 16*4096*2048*2 bytes, multiplier 1
    assert c["all-gather"] == 16 * 4096 * 2048 * 2
    # plain f32 all-reduce: 1024*512*4 * 2 (ring multiplier)
    # promoted one: same bytes but halved (bf16 wire) then x2 ring
    plain = 1024 * 512 * 4 * 2
    promoted = plain / 2
    start = 2 * 2 * 4 * 2
    assert c["all-reduce"] == plain + promoted + start
    assert c["reduce-scatter"] == 2 * 64 * 64 * 4
    assert c["collective-permute"] == 8 * 2
    assert c["all-to-all"] == 256 * 4 * 4
    assert c["counts"]["all-reduce"] == 3  # start counted once, done skipped


def test_roofline_terms_and_bottleneck():
    r = rl.Roofline(
        flops_per_device=197e12,  # exactly 1 s of compute
        bytes_per_device=819e9 / 2,  # 0.5 s of memory
        wire_bytes_per_device=200e9 * 2,  # 2 s of collective
        collective_detail={},
        chips=256,
        model_flops=197e12 * 256 * 0.5,
    )
    assert r.bottleneck == "collective"
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_step - 2.0) < 1e-9
    assert abs(r.mfu_bound - 0.25) < 1e-9
    assert abs(r.useful_flops_fraction - 0.5) < 1e-9


def test_model_flops_modes():
    from repro.configs import SHAPES_BY_NAME

    cfg = get_config("tinyllama-1.1b")
    n = cfg.n_params()
    t = rl.model_flops(cfg, SHAPES_BY_NAME["train_4k"])
    assert abs(t - 6 * n * 256 * 4096) / t < 1e-9
    d = rl.model_flops(cfg, SHAPES_BY_NAME["decode_32k"])
    assert abs(d - 2 * n * 128) / d < 1e-9
    # MoE uses active params
    moe = get_config("moonshot-v1-16b-a3b")
    assert moe.n_active_params() < moe.n_params()
    tm = rl.model_flops(moe, SHAPES_BY_NAME["train_4k"])
    assert abs(tm - 6 * moe.n_active_params() * 256 * 4096) / tm < 1e-9


def test_n_params_sane():
    # analytic param counts in the right ballpark for known models
    assert 1.0e9 < get_config("tinyllama-1.1b").n_params() < 1.2e9
    assert 5.5e9 < get_config("yi-6b").n_params() < 6.5e9
    assert 300e9 < get_config("nemotron-4-340b").n_params() < 380e9
    assert 6.5e9 < get_config("falcon-mamba-7b").n_params() < 8.5e9
    # NB: the assigned pool config (48L x 64e x ff1408 gated) totals ~28.5B;
    # the "16b" in the pool id refers to the HF release whose depth differs.
    # We implement the assigned config verbatim (see configs/moonshot_*.py).
    m = get_config("moonshot-v1-16b-a3b")
    assert 25e9 < m.n_params() < 31e9
    assert 3.5e9 < m.n_active_params() < 5.5e9
    p = get_config("phi3.5-moe-42b-a6.6b")
    assert 39e9 < p.n_params() < 45e9
    assert 5.5e9 < p.n_active_params() < 8e9
