"""Pytest config: smoke tests run on the single real CPU device.

Multi-device tests (tests/test_distributed.py, test_context_parallel.py)
spawn subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=N
so this process never locks a fake device count (per spec).

``hypothesis`` is optional (unavailable in offline containers): the
property-test modules importorskip it themselves, and the profile below
is only registered when the package is importable.
"""

import os

try:
    # keep hypothesis deadlines off for jit-compiling properties
    from hypothesis import settings
except ImportError:
    settings = None

if settings is not None:
    settings.register_profile("repro", deadline=None, derandomize=True)
    settings.load_profile("repro")


def pytest_report_header(config):
    import jax

    return f"jax devices: {jax.device_count()} ({jax.devices()[0].platform})"
