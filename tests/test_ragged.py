"""Ragged-length hardening suite (PR 2).

Covers the length-aware Merge Path layers end to end:

* fuzzed ragged batched merges (>= 200 random ``(B, a_lens, b_lens)``
  row configurations, zero-length rows and sentinel-valued payloads
  included) against the per-row NumPy stable-merge oracle, on both the
  pure-JAX path and the 2-D-grid ragged Pallas kernel;
* residue-free ``partitioned_merge`` / ``segmented_merge{,_kv}``
  (non-divisible sizes, mid-segment input exhaustion, real ``+inf`` /
  ``iinfo.max`` keys, empty inputs);
* the int-overflow top-k fix (``iinfo.min`` payloads);
* pad handling in the distributed combine helpers;
* the ragged consumers: MoE padded-token dispatch and masked-vocab
  sampling.

Pure pytest (no hypothesis) so the whole file is tier-1 in offline
containers.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    flip_desc,
    merge_batched_ragged,
    merge_kv_batched_ragged,
    merge_k,
    merge_k_kv,
    merge_sort_batched_ragged,
    merge_sort_kv_batched_ragged,
    partitioned_merge,
    segmented_merge,
    segmented_merge_kv,
    stable_argsort_batched_ragged,
    topk_batched,
    topk_batched_ragged,
    topk_desc,
)
from repro.core.distributed import _pairwise_tree_merge
from repro.kernels import merge_batched_ragged_pallas, merge_kv_batched_ragged_pallas
from repro.kernels import ops

I32MAX = np.iinfo(np.int32).max
I32MIN = np.iinfo(np.int32).min


def ragged_rows(rng, b, n, dtype=np.int32, sentinel_rate=0.15):
    """Sorted (B, n) rows + random valid lengths; garbage beyond lengths.

    A slice of rows gets payloads *equal* to the padding sentinel
    (``iinfo.max`` / ``+inf``) inside the valid prefix, the classic
    collision case.
    """
    if np.issubdtype(dtype, np.integer):
        x = rng.integers(-1000, 1000, (b, n)).astype(dtype)
        sent = np.iinfo(dtype).max
    else:
        x = rng.standard_normal((b, n)).astype(dtype)
        sent = np.inf
    x = np.sort(x, axis=1)
    lens = rng.integers(0, n + 1, b).astype(np.int32)
    lens[rng.integers(0, b)] = 0  # always include an empty row
    for r in range(b):
        if rng.random() < sentinel_rate and lens[r] > 0:
            x[r, max(0, lens[r] - 2) : lens[r]] = sent  # real sentinel payloads
        # scribble on the padding region: the API must ignore it
        x[r, lens[r] :] = rng.permutation(x[r, lens[r] :])
    return x, lens


def np_merge_oracle(a_valid, b_valid):
    """Stable A-priority merge == stable sort of [A then B]."""
    return np.sort(np.concatenate([a_valid, b_valid]), kind="stable")


def np_merge_kv_oracle(ak, av, bk, bv):
    keys = np.concatenate([ak, bk])
    vals = np.concatenate([av, bv])
    order = np.argsort(keys, kind="stable")
    return keys[order], vals[order]


# --- fuzzed ragged batched merges (acceptance: >= 200 row configs) ----------


@pytest.mark.parametrize("dtype", [np.int32, np.float32])
def test_fuzz_merge_batched_ragged_vs_np_oracle(dtype):
    """13 batches x 8 rows x 2 dtypes = 208 random (lens_a, lens_b) row
    configurations, bit-identical to the per-row NumPy oracle."""
    rng = np.random.default_rng(0 if dtype is np.int32 else 1)
    B, na, nb = 8, 48, 64
    fn = jax.jit(merge_batched_ragged)
    sent = np.iinfo(dtype).max if np.issubdtype(dtype, np.integer) else np.inf
    for it in range(13):
        a, al = ragged_rows(rng, B, na, dtype)
        b, bl = ragged_rows(rng, B, nb, dtype)
        out = np.asarray(fn(jnp.array(a), jnp.array(b), jnp.array(al), jnp.array(bl)))
        for r in range(B):
            m = al[r] + bl[r]
            ref = np_merge_oracle(a[r, : al[r]], b[r, : bl[r]])
            np.testing.assert_array_equal(out[r, :m], ref)
            assert (out[r, m:] == sent).all()


def test_fuzz_merge_kv_batched_ragged_vs_np_oracle():
    """Ragged kv merges carry values exactly — incl. sentinel-equal keys."""
    rng = np.random.default_rng(2)
    B, na, nb = 8, 31, 17
    fn = jax.jit(merge_kv_batched_ragged)
    for it in range(8):
        ak, al = ragged_rows(rng, B, na, np.int32, sentinel_rate=0.5)
        bk, bl = ragged_rows(rng, B, nb, np.int32, sentinel_rate=0.5)
        av = rng.integers(0, 10**6, (B, na)).astype(np.int32)
        bv = rng.integers(0, 10**6, (B, nb)).astype(np.int32)
        ko, vo = fn(
            jnp.array(ak), jnp.array(av), jnp.array(bk), jnp.array(bv),
            jnp.array(al), jnp.array(bl),
        )
        ko, vo = np.asarray(ko), np.asarray(vo)
        for r in range(B):
            m = al[r] + bl[r]
            rk, rv = np_merge_kv_oracle(
                ak[r, : al[r]], av[r, : al[r]], bk[r, : bl[r]], bv[r, : bl[r]]
            )
            np.testing.assert_array_equal(ko[r, :m], rk)
            np.testing.assert_array_equal(vo[r, :m], rv)
            assert (ko[r, m:] == I32MAX).all() and (vo[r, m:] == 0).all()


def test_fuzz_ragged_pallas_kernel_vs_np_oracle():
    """The 2-D-grid ragged kernel (lengths via scalar prefetch) matches the
    oracle bit-exactly across random lengths and non-divisible tiles."""
    rng = np.random.default_rng(3)
    B, na, nb, tile = 8, 70, 45, 64  # (na+nb) % tile != 0
    fn = jax.jit(
        lambda a, b, al, bl: merge_batched_ragged_pallas(a, b, al, bl, tile=tile)
    )
    for it in range(3):
        a, al = ragged_rows(rng, B, na, np.float32)
        b, bl = ragged_rows(rng, B, nb, np.float32)
        out = np.asarray(fn(jnp.array(a), jnp.array(b), jnp.array(al), jnp.array(bl)))
        for r in range(B):
            m = al[r] + bl[r]
            np.testing.assert_array_equal(
                out[r, :m], np_merge_oracle(a[r, : al[r]], b[r, : bl[r]])
            )
            assert (out[r, m:] == np.inf).all()


def test_ragged_pallas_kv_kernel_sentinel_keys():
    rng = np.random.default_rng(4)
    B, na, nb, tile = 4, 80, 50, 64
    ak, al = ragged_rows(rng, B, na, np.int32, sentinel_rate=1.0)
    bk, bl = ragged_rows(rng, B, nb, np.int32, sentinel_rate=1.0)
    av = rng.integers(0, 10**6, (B, na)).astype(np.int32)
    bv = rng.integers(0, 10**6, (B, nb)).astype(np.int32)
    ko, vo = merge_kv_batched_ragged_pallas(
        jnp.array(ak), jnp.array(av), jnp.array(bk), jnp.array(bv),
        jnp.array(al), jnp.array(bl), tile=tile,
    )
    rk, rv = merge_kv_batched_ragged(
        jnp.array(ak), jnp.array(av), jnp.array(bk), jnp.array(bv),
        jnp.array(al), jnp.array(bl),
    )
    np.testing.assert_array_equal(np.asarray(ko), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(vo), np.asarray(rv))


def test_ops_ragged_dispatch_both_paths():
    rng = np.random.default_rng(5)
    a, al = ragged_rows(rng, 4, 100, np.float32)
    b, bl = ragged_rows(rng, 4, 80, np.float32)
    args = (jnp.array(a), jnp.array(b), jnp.array(al), jnp.array(bl))
    ref = np.asarray(merge_batched_ragged(*args))
    np.testing.assert_array_equal(np.asarray(ops.merge_batched_ragged(*args, tile=512)), ref)
    np.testing.assert_array_equal(np.asarray(ops.merge_batched_ragged(*args, tile=64)), ref)


# --- ragged sorts / argsort / top-k -----------------------------------------


def test_merge_sort_batched_ragged_matches_np():
    rng = np.random.default_rng(6)
    B, n = 6, 90
    x = rng.standard_normal((B, n)).astype(np.float32)
    x[0, :4] = np.inf  # real sentinel payloads inside the valid prefix
    lens = rng.integers(0, n + 1, B).astype(np.int32)
    lens[0] = 10
    out = np.asarray(merge_sort_batched_ragged(jnp.array(x), jnp.array(lens)))
    for r in range(B):
        np.testing.assert_array_equal(out[r, : lens[r]], np.sort(x[r, : lens[r]]))
        assert (out[r, lens[r] :] == np.inf).all()


def test_stable_argsort_batched_ragged_is_permutation():
    rng = np.random.default_rng(7)
    B, n = 5, 40
    keys = rng.integers(0, 6, (B, n)).astype(np.int32)
    lens = np.array([40, 17, 0, 1, 33], np.int32)
    perm = np.asarray(stable_argsort_batched_ragged(jnp.array(keys), jnp.array(lens)))
    for r in range(B):
        np.testing.assert_array_equal(
            perm[r, : lens[r]], np.argsort(keys[r, : lens[r]], kind="stable")
        )
        np.testing.assert_array_equal(np.sort(perm[r]), np.arange(n))  # full permutation


def test_topk_batched_ragged_matches_lax_topk_per_row():
    rng = np.random.default_rng(8)
    B, n, k = 6, 64, 9
    x = rng.standard_normal((B, n)).astype(np.float32)
    x[1, :3] = -np.inf  # banned-token logits
    lens = np.array([64, 64, 20, 9, 4, 0], np.int32)
    v, i = topk_batched_ragged(jnp.array(x), k, jnp.array(lens))
    v, i = np.asarray(v), np.asarray(i)
    for r in range(B):
        kk = min(k, lens[r])
        if kk:
            rv, ri = jax.lax.top_k(jnp.array(x[r, : lens[r]]), kk)
            np.testing.assert_array_equal(v[r, :kk], np.asarray(rv))
            np.testing.assert_array_equal(i[r, :kk], np.asarray(ri))
        assert (i[r, kk:] == -1).all() and (v[r, kk:] == -np.inf).all()


# --- int-overflow top-k fix (satellite) -------------------------------------


def test_topk_desc_iinfo_min_regression():
    """``keys = -x`` wraps at iinfo.min; flip_desc must not."""
    x = np.array([5, I32MIN, 7, I32MIN, I32MAX, 0, I32MAX], np.int32)
    v, i = topk_desc(jnp.array(x), x.size)
    rv, ri = jax.lax.top_k(jnp.array(x), x.size)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


def test_topk_batched_int_extremes():
    x = np.array(
        [[I32MIN, 3, I32MAX, I32MIN], [I32MAX, I32MAX, I32MIN, 0]], np.int32
    )
    v, i = topk_batched(jnp.array(x), 4)
    rv, ri = jax.lax.top_k(jnp.array(x), 4)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


def test_flip_desc_is_exact_order_reversal():
    x = np.array([I32MIN, I32MIN + 1, -1, 0, 1, I32MAX - 1, I32MAX], np.int32)
    f = np.asarray(flip_desc(jnp.array(x)))
    assert (np.diff(f) < 0).all()  # strictly decreasing image of increasing input
    assert f[0] == I32MAX and f[-1] == I32MIN


# --- residue-free partitioned / segmented merges (satellite) ----------------


@pytest.mark.parametrize("p", [1, 3, 5, 7, 13])
def test_partitioned_merge_non_divisible(p):
    rng = np.random.default_rng(100 + p)
    a = np.sort(rng.integers(-100, 100, 23)).astype(np.int32)
    b = np.sort(rng.integers(-100, 100, 18)).astype(np.int32)
    out = np.asarray(partitioned_merge(jnp.array(a), jnp.array(b), p))
    np.testing.assert_array_equal(out, np_merge_oracle(a, b))


@pytest.mark.parametrize("seg", [3, 7, 16])
def test_segmented_merge_non_divisible_and_exhaustion(seg):
    """One input exhausted mid-segment: tiny A against long B, and
    duplicate keys equal to the int sentinel."""
    rng = np.random.default_rng(200 + seg)
    a = np.sort(rng.integers(-10, 10, 3)).astype(np.int32)
    b = np.sort(rng.integers(-10, 10, 41)).astype(np.int32)
    b[-3:] = I32MAX  # duplicate sentinel-equal keys
    out = np.asarray(segmented_merge(jnp.array(a), jnp.array(b), seg))
    np.testing.assert_array_equal(out, np_merge_oracle(a, b))


def test_segmented_merge_empty_sides():
    e = jnp.array([], jnp.int32)
    a = jnp.array([1, 5, 9], jnp.int32)
    np.testing.assert_array_equal(np.asarray(segmented_merge(a, e, 2)), [1, 5, 9])
    np.testing.assert_array_equal(np.asarray(segmented_merge(e, a, 4)), [1, 5, 9])
    assert np.asarray(segmented_merge(e, e, 4)).shape == (0,)
    with pytest.raises(ValueError):
        segmented_merge(a, e, 0)


def test_partitioned_merge_empty_sides_and_inf():
    e = jnp.array([], jnp.float32)
    a = jnp.array([-np.inf, 0.0, np.inf], jnp.float32)
    np.testing.assert_array_equal(np.asarray(partitioned_merge(a, e, 4)), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(partitioned_merge(e, a, 4)), np.asarray(a))
    b = jnp.array([np.inf, np.inf], jnp.float32)
    out = np.asarray(partitioned_merge(a, b, 2))
    np.testing.assert_array_equal(out, [-np.inf, 0.0, np.inf, np.inf, np.inf])


def test_segmented_merge_kv_sentinel_keys_carry_values():
    """Real +inf keys mid-stream must keep their values: pre-fix, window
    pads shadowed them and surfaced zeros."""
    af = np.array([-2.0, 1.0, np.inf], np.float32)
    bf = np.array([-1.0, np.inf, np.inf, np.inf, np.inf, np.inf, np.inf, np.inf, np.inf], np.float32)
    av = np.array([10.0, 11.0, 12.0], np.float32)
    bv = 100.0 + np.arange(9, dtype=np.float32)
    ko, vo = segmented_merge_kv(
        jnp.array(af), jnp.array(av), jnp.array(bf), jnp.array(bv), 4
    )
    rk, rv = np_merge_kv_oracle(af, av, bf, bv)
    np.testing.assert_array_equal(np.asarray(ko), rk)
    np.testing.assert_array_equal(np.asarray(vo), rv)


def test_segmented_merge_kv_non_divisible():
    rng = np.random.default_rng(9)
    ak = np.sort(rng.integers(0, 50, 13)).astype(np.int32)
    bk = np.sort(rng.integers(0, 50, 29)).astype(np.int32)
    av = np.arange(13, dtype=np.float32)
    bv = 100 + np.arange(29, dtype=np.float32)
    ko, vo = segmented_merge_kv(
        jnp.array(ak), jnp.array(av), jnp.array(bk), jnp.array(bv), 16
    )
    rk, rv = np_merge_kv_oracle(ak, av, bk, bv)
    np.testing.assert_array_equal(np.asarray(ko), rk)
    np.testing.assert_array_equal(np.asarray(vo), rv)


# --- k-way and distributed combine helpers (satellites) ---------------------


def test_merge_k_ragged_lens_with_sentinel_payloads():
    rng = np.random.default_rng(10)
    runs = np.sort(rng.integers(-20, 20, (5, 8)), axis=1).astype(np.int32)
    runs[0, -2:] = I32MAX  # real iinfo.max data in a *short* run's prefix
    lens = np.array([8, 3, 0, 5, 8], np.int32)
    out = np.asarray(merge_k(jnp.array(runs), lens=jnp.array(lens)))
    ref = np.sort(
        np.concatenate([runs[j, : lens[j]] for j in range(5)]), kind="stable"
    )
    np.testing.assert_array_equal(out[: lens.sum()], ref)
    assert (out[lens.sum() :] == I32MAX).all()


def test_merge_k_kv_duplicate_max_keys():
    """The pre-ragged tournament interleaved pads ahead of later runs' real
    iinfo.max keys, leaking zero values into the trimmed result."""
    kk = np.array([[0, 1], [I32MAX, I32MAX], [2, I32MAX]], np.int32)
    vv = np.array([[10, 11], [20, 21], [30, 31]], np.int32)
    mk, mv = merge_k_kv(jnp.array(kk), jnp.array(vv))
    # run-major stable flatten == run-priority tie-break
    order = np.argsort(kk.reshape(-1), kind="stable")
    np.testing.assert_array_equal(np.asarray(mk), kk.reshape(-1)[order])
    np.testing.assert_array_equal(np.asarray(mv), vv.reshape(-1)[order])


def test_merge_k_identity_with_lens_normalizes_tail():
    """k == 1 runs no merge round; caller-lens tails must still come back
    sentinel-normalized (keys) / zeroed (values), per the contract."""
    x = np.array([[1, 2, 3, 7, 0]], np.int32)
    out = np.asarray(merge_k(jnp.array(x), lens=jnp.array([3])))
    np.testing.assert_array_equal(out, [1, 2, 3, I32MAX, I32MAX])
    v = np.array([[10, 20, 30, 40, 50]], np.int32)
    ko, vo = merge_k_kv(jnp.array(x), jnp.array(v), lens=jnp.array([3]))
    np.testing.assert_array_equal(np.asarray(ko), [1, 2, 3, I32MAX, I32MAX])
    np.testing.assert_array_equal(np.asarray(vo), [10, 20, 30, 0, 0])


def test_pairwise_tree_merge_duplicate_max():
    """Tie-break doc'd behavior: lower-indexed run first; int runs whose
    data contains iinfo.max merge exactly (satellite regression)."""
    runs = np.array(
        [[1, 5, I32MAX, I32MAX], [2, I32MAX, I32MAX, I32MAX], [0, 3, 4, I32MAX]],
        np.int32,
    )
    out = np.asarray(_pairwise_tree_merge(jnp.array(runs)))
    np.testing.assert_array_equal(out, np.sort(runs.reshape(-1), kind="stable"))
    # ragged form: only the valid prefixes participate
    lens = np.array([2, 4, 1], np.int32)
    out = np.asarray(_pairwise_tree_merge(jnp.array(runs), lens=jnp.array(lens)))
    ref = np.sort(np.concatenate([runs[j, : lens[j]] for j in range(3)]), kind="stable")
    np.testing.assert_array_equal(out[: lens.sum()], ref)
    assert (out[lens.sum() :] == I32MAX).all()


# --- ragged consumers: MoE padded tokens, masked-vocab sampling -------------


def test_moe_token_counts_padding_invariance():
    import dataclasses

    from repro.configs import get_config
    from repro.models import init_params
    from repro.models.moe import moe_apply

    base = get_config("phi3.5-moe-42b-a6.6b").reduced()
    cfg = dataclasses.replace(
        base, num_experts=8, experts_per_token=2, moe_dispatch="merge_path"
    )
    params = init_params(cfg, jax.random.key(0))
    layer0 = jax.tree.map(lambda t: t[0], params["layers"])
    x = jax.random.normal(jax.random.key(1), (3, 32, cfg.d_model))
    # full counts == no counts, bit-compatible
    y_full = moe_apply(layer0["moe"], x, cfg)
    y_cnt = moe_apply(layer0["moe"], x, cfg, token_counts=jnp.array([32, 32, 32]))
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_cnt), rtol=1e-6)
    # padding tokens must not affect valid outputs (they can't steal capacity)
    counts = jnp.array([32, 20, 7])
    y1 = moe_apply(layer0["moe"], x, cfg, token_counts=counts)
    x2 = x.at[1, 20:].set(99.0).at[2, 7:].set(-3.0)
    y2 = moe_apply(layer0["moe"], x2, cfg, token_counts=counts)
    for r, c in enumerate(np.asarray(counts)):
        np.testing.assert_allclose(
            np.asarray(y1)[r, :c], np.asarray(y2)[r, :c], rtol=1e-5, atol=1e-5
        )


def test_topk_batched_ragged_k_exceeds_width():
    """k > n truncates to the row width like topk_batched / lax.top_k,
    instead of crashing on a broadcast mismatch."""
    rng = np.random.default_rng(12)
    x = rng.standard_normal((3, 10)).astype(np.float32)
    lens = np.array([10, 4, 0], np.int32)
    v, i = topk_batched_ragged(jnp.array(x), 128, jnp.array(lens))
    assert v.shape == (3, 10) and i.shape == (3, 10)
    rv, ri = jax.lax.top_k(jnp.array(x[0]), 10)
    np.testing.assert_array_equal(np.asarray(v)[0], np.asarray(rv))
    assert (np.asarray(i)[2] == -1).all()


def test_sampler_empty_vocab_row_returns_minus_one():
    """A vocab_lens == 0 row deterministically samples -1 (documented
    out-of-band marker); live rows are never contaminated."""
    from repro.serving.sampler import topk_sample

    rng = np.random.default_rng(13)
    logits = rng.standard_normal((3, 64)).astype(np.float32)
    for seed in range(3):
        s = np.asarray(
            topk_sample(jnp.array(logits), jax.random.key(seed), k=8,
                        vocab_lens=jnp.array([0, 5, 64]))
        )
        assert s[0] == -1 and 0 <= s[1] < 5 and 0 <= s[2] < 64


def test_sampler_masked_vocab():
    from repro.serving.sampler import topk_sample, topp_sample

    rng = np.random.default_rng(11)
    logits = rng.standard_normal((4, 64)).astype(np.float32)
    lens = np.array([64, 30, 10, 5])
    for seed in range(3):
        s = np.asarray(
            topk_sample(jnp.array(logits), jax.random.key(seed), k=8,
                        vocab_lens=jnp.array(lens))
        )
        assert (s >= 0).all() and (s < lens).all()
        # a padded row samples identically to its unpadded truncation
        s_trunc = np.asarray(
            topk_sample(jnp.array(logits[1:2, :30]), jax.random.key(seed), k=8)
        )
        s_rag = np.asarray(
            topk_sample(jnp.array(logits[1:2]), jax.random.key(seed), k=8,
                        vocab_lens=jnp.array([30]))
        )
        assert s_trunc[0] == s_rag[0]
        sp = np.asarray(
            topp_sample(jnp.array(logits), jax.random.key(seed), p=0.8, k_max=8,
                        vocab_lens=jnp.array(lens))
        )
        assert (sp >= 0).all() and (sp < lens).all()
