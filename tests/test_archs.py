"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, shape + finiteness assertions; prefill/decode consistency."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import SHAPES_BY_NAME, TrainConfig, get_config, list_archs, shape_applicable
from repro.models import (
    forward_decode,
    forward_prefill,
    forward_train,
    init_caches,
    init_params,
)
from repro.train.steps import init_train_state, make_train_step

ARCHS = list_archs()
B, S = 2, 12


def _batch(cfg, key, s=S, with_labels=True):
    toks = jax.random.randint(key, (B, s), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if with_labels:
        batch["labels"] = toks
    if cfg.num_prefix_tokens:
        batch["prefix_emb"] = jax.random.normal(key, (B, cfg.num_prefix_tokens, cfg.d_model))
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(0))
    logits = forward_train(cfg, params, _batch(cfg, jax.random.key(1), with_labels=False))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=4)
    state = init_train_state(cfg, tcfg, jax.random.key(0))
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = _batch(cfg, jax.random.key(1))
    state, m = step(state, batch)
    state, m2 = step(state, batch)
    assert np.isfinite(float(m["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) <= float(m["loss"]) + 0.5
    assert int(state["step"]) == 2


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    params = init_params(cfg, jax.random.key(0))
    key = jax.random.key(42)
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    batch_full = _batch(cfg, key, with_labels=False)
    batch_full["tokens"] = toks
    batch_pre = dict(batch_full)
    batch_pre["tokens"] = toks[:, :S]
    full_logits = forward_train(cfg, params, batch_full)
    pre_pos = cfg.num_prefix_tokens  # paligemma offsets positions by the prefix
    cache_len = pre_pos + S + 1
    last, caches, enc_kv = forward_prefill(cfg, params, batch_pre, cache_len=cache_len)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full_logits[:, S - 1]), rtol=3e-4, atol=3e-4
    )
    dec, _ = forward_decode(
        cfg, params, caches, toks[:, S : S + 1],
        jnp.full((B,), pre_pos + S, jnp.int32), enc_kv=enc_kv,
    )
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full_logits[:, S]), rtol=5e-4, atol=5e-4
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_shape_cell_applicability(arch):
    """Every (arch x shape) cell is either applicable or has a recorded reason."""
    cfg = get_config(arch)
    for name in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        ok, why = shape_applicable(cfg, SHAPES_BY_NAME[name])
        if not ok:
            assert name == "long_500k" and not cfg.subquadratic
            assert why


def test_sliding_window_decode_ring_buffer():
    """Decode with a ring-buffer cache matches full attention restricted to
    the window (hymba reduced config)."""
    cfg = get_config("hymba-1.5b").reduced()
    params = init_params(cfg, jax.random.key(0))
    n = 24  # > window (16) to exercise wraparound
    toks = jax.random.randint(jax.random.key(5), (B, n), 0, cfg.vocab_size)
    caches = init_caches(cfg, B, n)
    logits = None
    for t in range(n):
        logits, caches = forward_decode(
            cfg, params, caches, toks[:, t : t + 1], jnp.full((B,), t, jnp.int32)
        )
    assert np.isfinite(np.asarray(logits)).all()


def test_param_counts_match_analytic():
    for arch in ARCHS:
        cfg = get_config(arch)
        from repro.models import abstract_params

        tree = abstract_params(cfg)
        total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))
        analytic = cfg.n_params()
        assert abs(total - analytic) / analytic < 0.02, (arch, total, analytic)
