"""Telemetry subsystem: spans, metrics, deterministic traces (PR 9).

Covers the core registry (span nesting/ordering, counters, exact
percentiles vs numpy), the Perfetto export round trip, the byte-identity
contract — a fault-injected serving workload replayed under the tick
clock serializes to identical bytes — the Cor. 7 balance gauge recorded
by the distributed layer, the ``python -m repro.telemetry`` CLI, the
cross-process snapshot/merge path used by ``bench_distributed``, and
lint rule L007 (no raw wall-clock reads outside the telemetry layer).
"""

import json
import sys
from pathlib import Path

import numpy as np
import pytest
import jax
import jax.numpy as jnp

REPO_ROOT = Path(__file__).resolve().parent.parent
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))

from tools import lint_rules  # noqa: E402

from repro import telemetry  # noqa: E402
from repro.telemetry import (  # noqa: E402
    TICK_SCALE,
    Histogram,
    Telemetry,
    TickClock,
    chrome_trace,
    get_telemetry,
    summary,
    trace_json_bytes,
    write_trace,
)
from repro.telemetry.__main__ import main as telemetry_cli  # noqa: E402


# ---------------------------------------------------------------------------
# core registry
# ---------------------------------------------------------------------------


def test_span_nesting_depth_and_ordering():
    tel = Telemetry(clock=TickClock())
    with tel.span("outer", kind="test") as outer:
        with tel.span("inner") as inner:
            pass
        with tel.span("inner") as inner2:
            inner2.set("served_by", "core")
    assert [sp.name for sp in tel.spans] == ["outer", "inner", "inner"]
    assert outer.depth == 0 and inner.depth == 1 and inner2.depth == 1
    # TickClock timestamps are strictly increasing per read
    assert outer.start < inner.start < inner.end < inner2.start < inner2.end < outer.end
    assert outer.attrs == {"kind": "test"}
    assert inner2.attrs["served_by"] == "core"
    stats = tel.span_stats()
    assert stats["inner"]["count"] == 2
    assert stats["outer"]["count"] == 1
    assert tel.unclosed() == []


def test_unclosed_span_detection_and_exception_unwind():
    tel = Telemetry(clock=TickClock())
    dangling = tel.begin("dangling")
    assert tel.unclosed() == [dangling]
    # an exception that unwinds several nested spans leaves none half-open
    with pytest.raises(RuntimeError):
        with tel.span("a"):
            with tel.span("b"):
                raise RuntimeError("boom")
    assert tel.unclosed() == [dangling]
    assert chrome_trace(tel)["otherData"]["unclosed_spans"] == 1


def test_counters_and_gauges_exact():
    tel = Telemetry()
    tel.counter("c").add()
    tel.counter("c").add(41)
    assert tel.counters["c"].value == 42
    g = tel.gauge("g")
    for v in (3, 1, 2):
        g.set(v)
    assert g.as_dict() == {"last": 2, "min": 1, "max": 3}


def test_tick_clock_is_pure_function_of_event_stream():
    c1, c2 = TickClock(), TickClock()
    for c in (c1, c2):
        c.advance(5)
    assert c1.now() == c2.now() == 5 * TICK_SCALE
    assert c1.now() == 5 * TICK_SCALE + 1
    c1.advance(6)
    assert c1.now() == 6 * TICK_SCALE  # seq resets on advance


def test_histogram_percentiles_match_numpy():
    rng = np.random.default_rng(7)
    samples = rng.exponential(scale=500.0, size=257)
    h = Histogram()
    for s in samples:
        h.record(s)
    for q in (0, 10, 50, 95, 99, 100):
        assert h.percentile(q) == pytest.approx(np.percentile(samples, q), rel=1e-12)
    st = h.stats()
    assert st["count"] == len(samples)
    assert st["mean"] == pytest.approx(samples.mean())
    assert sum(c for _, c in st["buckets"]) == len(samples)


def test_use_installs_isolated_registry():
    root = get_telemetry()
    with telemetry.use(Telemetry()) as tel:
        assert get_telemetry() is tel is not root
        tel.counter("x").add()
    assert get_telemetry() is root
    assert "x" not in root.counters


# ---------------------------------------------------------------------------
# export / round trip
# ---------------------------------------------------------------------------


def test_chrome_trace_round_trips_through_json(tmp_path):
    tel = Telemetry(clock=TickClock())
    tel.counter("calls").add(3)
    tel.gauge("depth").set(2)
    with tel.span("tick", tick=1):
        with tel.span("op/merge", n=128):
            pass
    trace = chrome_trace(tel)
    assert json.loads(trace_json_bytes(tel)) == trace
    evs = trace["traceEvents"]
    assert [e["ph"] for e in evs] == ["X", "X"]
    assert evs[0]["tid"] == 1 and evs[1]["tid"] == 2  # tid = 1 + depth
    # TickClock hands out 0,1,2,3 → outer spans [0,3], inner [1,2]
    assert (evs[0]["ts"], evs[0]["dur"]) == (0, 3)
    assert (evs[1]["ts"], evs[1]["dur"]) == (1, 1)
    p = tmp_path / "t.json"
    write_trace(tel, p)
    assert json.loads(p.read_bytes()) == trace
    # histograms are summary-only: never in the trace body
    tel.histogram("wall_us").record(123.0)
    assert "histograms" not in chrome_trace(tel)["otherData"]
    assert summary(tel)["histograms"]["wall_us"]["count"] == 1


def test_snapshot_merge_across_process_boundary():
    src = Telemetry()
    src.counter("distributed.exchange_calls").add(4)
    src.gauge("distributed.balance_ratio").set(1.0)
    src.gauge("distributed.balance_ratio").set(1.02)
    src.histogram("bench/x").record(10.0)
    src.histogram("bench/x").record(20.0)
    snap = json.loads(json.dumps(src.snapshot()))  # as it crosses the pipe
    dst = Telemetry()
    dst.counter("distributed.exchange_calls").add(1)
    dst.merge_snapshot(snap)
    assert dst.counters["distributed.exchange_calls"].value == 5
    g = dst.gauges["distributed.balance_ratio"].as_dict()
    assert g["min"] == 1.0 and g["max"] == 1.02 and g["last"] == 1.02
    assert dst.histograms["bench/x"].count == 2


# ---------------------------------------------------------------------------
# instrumented layers
# ---------------------------------------------------------------------------


def test_distributed_merge_records_balance_and_windows():
    from repro.core import distributed_merge

    rng = np.random.default_rng(3)
    a = jnp.asarray(np.sort(rng.standard_normal(256)).astype(np.float32))
    b = jnp.asarray(np.sort(rng.standard_normal(192)).astype(np.float32))
    with telemetry.use(Telemetry()) as tel:
        out = distributed_merge(a, b)
        assert np.asarray(out).shape == (448,)
        bal = tel.gauges["distributed.balance_ratio"].as_dict()
        assert bal["max"] is not None and bal["max"] <= 1.05  # Cor. 7
        # every element lands in exactly one device window
        windows = [
            c.value for k, c in tel.counters.items()
            if k.startswith("distributed.window_elems.dev")
        ]
        assert sum(windows) == 448
        assert tel.counters["distributed.exchange_bytes.window_payload"].value > 0
        assert any(name.startswith("op/") for name in tel.span_stats())
        assert tel.unclosed() == []


def test_guarded_call_span_carries_dispatch_label():
    from repro.runtime import resilience as res

    with telemetry.use(Telemetry()) as tel:
        out = res.guarded_call(
            "merge", [("pallas", lambda: 7)], meta={"n": 4, "tile": None}
        )
        assert out == 7
        (sp,) = [s for s in tel.spans if s.name == "op/merge"]
        assert sp.attrs["served_by"] == "pallas"
        assert sp.attrs["n"] == 4 and "tile" not in sp.attrs  # None filtered
        assert tel.health["merge"].calls == 1


def _serving_run(params, cfg):
    """One deterministic fault-injected serving workload; returns
    (report, trace bytes) recorded in a fresh registry."""
    from repro.runtime import faults
    from repro.serving.engine import Request, ServingEngine

    with telemetry.use(Telemetry()) as tel, faults.inject("launch:serving.decode:1"):
        eng = ServingEngine(cfg, params, batch=2, max_seq=32)
        rng = np.random.default_rng(0)
        for i in range(3):
            eng.submit(Request(uid=i,
                               prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                               max_new_tokens=2, temperature=0.0))
        rep = eng.run_until_done()
        return rep, trace_json_bytes(tel)


def test_fault_injected_replay_is_byte_identical():
    """The acceptance bar: same workload + same fault plan, replayed in a
    fresh registry under the engine tick clock, serializes to *identical
    bytes* — timestamps are a pure function of the event stream."""
    from repro.configs import get_config
    from repro.models import init_params

    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_params(cfg, jax.random.key(0))
    rep1, raw1 = _serving_run(params, cfg)
    rep2, raw2 = _serving_run(params, cfg)
    assert rep1.completed == 3 == rep2.completed
    assert raw1 == raw2
    trace = json.loads(raw1)
    assert trace["otherData"]["unclosed_spans"] == 0
    ticks = [e for e in trace["traceEvents"] if e["name"] == "serving.tick"]
    assert len(ticks) == rep1.ticks
    # tick span timestamps sit exactly on the tick grid
    assert all(e["ts"] % TICK_SCALE < TICK_SCALE // 2 for e in ticks)
    # the ServingReport carries the summary block
    for key in ("tick_wall_us", "ticks_to_first_token", "ticks_per_token",
                "slot_occupancy", "queue_depth"):
        assert key in rep1.telemetry, rep1.telemetry.keys()
    assert rep1.telemetry["ticks_to_first_token"]["count"] == 3


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _write(tel, path):
    write_trace(tel, path)
    return str(path)


def test_cli_check_and_diff(tmp_path, capsys):
    tel = Telemetry(clock=TickClock())
    with tel.span("tick"):
        pass
    tel.gauge("distributed.balance_ratio").set(1.01)
    good = _write(tel, tmp_path / "good.json")
    assert telemetry_cli(["--check", good]) == 0
    assert "balance_ratio max=1.0100" in capsys.readouterr().out

    # unhealthy: an unclosed span and a Cor. 7 violation
    tel.begin("leaky")
    tel.gauge("distributed.balance_ratio").set(1.5)
    bad = _write(tel, tmp_path / "bad.json")
    assert telemetry_cli(["--check", bad]) == 1
    out = capsys.readouterr().out
    assert "unclosed span" in out and "Cor. 7" in out

    # summarize + diff modes exit 0 and name the drifted metric
    assert telemetry_cli([good]) == 0
    assert telemetry_cli([good, bad]) == 0
    out = capsys.readouterr().out
    assert "distributed.balance_ratio" in out and "leaky" in out


# ---------------------------------------------------------------------------
# lint rule L007
# ---------------------------------------------------------------------------


def _lint(src, path="src/repro/core/fixture.py"):
    return lint_rules.lint_source(src, path)


def test_l007_fires_on_raw_wall_clock():
    vs = _lint("import time\nt0 = time.perf_counter()\n")
    assert any(v.rule == "L007" for v in vs)
    vs = _lint("import time\nt0 = time.monotonic()\n")
    assert any(v.rule == "L007" for v in vs)
    vs = _lint("from time import perf_counter\n")
    assert any(v.rule == "L007" for v in vs)


def test_l007_suppression_and_sanctioned_paths():
    src = "import time\nt0 = time.perf_counter()  # lint: ok(L007)\n"
    assert not any(v.rule == "L007" for v in _lint(src))
    clean = "import time\nt0 = time.perf_counter()\n"
    assert not _lint(clean, path="src/repro/telemetry/spans.py")
    assert not _lint(clean, path="benchmarks/_timing.py")
    # time.time / sleep are not timing reads — out of scope
    assert not any(v.rule == "L007" for v in _lint("import time\ntime.sleep(0)\n"))
