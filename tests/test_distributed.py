"""Multi-device tests for core.distributed — run in a subprocess with 8
fake CPU devices so the main pytest process keeps 1 device (per spec)."""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8, timeout: int = 600) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"


def test_distributed_merge():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed_merge
        rng = np.random.default_rng(1)
        for na, nb in [(512, 512), (768, 256), (256, 768)]:
            a = np.sort(rng.standard_normal(na)).astype(np.float32)
            b = np.sort(rng.standard_normal(nb)).astype(np.float32)
            out = np.asarray(distributed_merge(jnp.array(a), jnp.array(b)))
            assert np.allclose(out, np.sort(np.concatenate([a, b])))
        print("ok")
    """)


def test_distributed_sort():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed_sort
        rng = np.random.default_rng(2)
        x = rng.standard_normal(2048).astype(np.float32)
        s, cnt, ovf = distributed_sort(jnp.array(x))
        s, cnt, ovf = np.asarray(s), np.asarray(cnt), np.asarray(ovf)
        assert not ovf
        P = 8; percap = s.shape[0] // P
        got = np.concatenate([s[i*percap:i*percap+cnt[i]] for i in range(P)])
        assert np.allclose(got, np.sort(x))
        print("ok")
    """)


def test_distributed_topk():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed_topk
        rng = np.random.default_rng(3)
        x = rng.standard_normal(4096).astype(np.float32)
        v, i = distributed_topk(jnp.array(x), 16)
        rv, ri = jax.lax.top_k(jnp.array(x), 16)
        assert np.allclose(np.asarray(v), np.asarray(rv))
        assert (np.asarray(i) == np.asarray(ri)).all()
        print("ok")
    """)


def test_exchange_window_vs_gather_fuzz():
    """The bandwidth-optimal window exchange is bit-identical to the
    all-gather oracle: duplicates, sentinel-tied kv keys, ragged /
    non-divisible shards, P in {2, 4, 8}, keys-only / kv / batched — and
    the max-window/max-piece bounds of window_bounds() really bound the
    true windows (so the fixed-size exchange buffers can never silently
    truncate)."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core import (distributed_merge, distributed_merge_kv,
                                distributed_merge_kv_batched, window_bounds)

        def np_cuts(a, b, diags):
            # numpy oracle for the A-priority diagonal intersections
            out = []
            for d in diags:
                lo, hi = max(0, d - len(b)), min(d, len(a))
                while lo < hi:
                    mid = (lo + hi) // 2
                    if a[min(mid, len(a)-1)] <= b[min(max(d-1-mid, 0), len(b)-1)]:
                        lo = mid + 1
                    else:
                        hi = mid
                out.append(lo)
            return np.array(out)

        def np_merge_kv(ak, av, bk, bv):
            # stable A-priority kv merge oracle
            keys = np.concatenate([ak, bk])
            vals = np.concatenate([av, bv])
            perm = np.argsort(keys, kind="stable")
            return keys[perm], vals[perm]

        rng = np.random.default_rng(11)
        devs = jax.devices()
        M = np.iinfo(np.int32).max
        cases = [  # (P, na, nb, flavor)
            (8, 513, 511, "dup"),     # duplicates, non-divisible
            (8, 64, 1000, "float"),   # skewed sizes
            (4, 37, 300, "dup"),      # ragged small prime
            (4, 96, 96, "sentinel"),  # kv keys tied with the pad sentinel
            (2, 7, 250, "sentinel"),
            (8, 129, 255, "batched"), # batched kv rows
        ]
        for p, na, nb, flavor in cases:
            mesh = Mesh(np.array(devs[:p]), ("x",))
            if flavor == "float":
                a = np.sort(rng.standard_normal(na)).astype(np.float32)
                b = np.sort(rng.standard_normal(nb)).astype(np.float32)
                w = np.asarray(distributed_merge(jnp.array(a), jnp.array(b), mesh, exchange="window"))
                g = np.asarray(distributed_merge(jnp.array(a), jnp.array(b), mesh, exchange="gather"))
                assert np.array_equal(w, np.sort(np.concatenate([a, b]))), (p, na, nb)
                assert np.array_equal(w, g), (p, na, nb)
            elif flavor in ("dup", "sentinel"):
                ak = np.sort(rng.integers(-4, 4, na)).astype(np.int32)
                bk = np.sort(rng.integers(-4, 4, nb)).astype(np.int32)
                if flavor == "sentinel":  # real payload keys == pad sentinel
                    ak[-3:] = M; bk[-2:] = M
                av = np.arange(na, dtype=np.int32)
                bv = 10_000 + np.arange(nb, dtype=np.int32)
                args = (jnp.array(ak), jnp.array(av), jnp.array(bk), jnp.array(bv))
                kw, vw = distributed_merge_kv(*args, mesh=mesh, exchange="window")
                kg, vg = distributed_merge_kv(*args, mesh=mesh, exchange="gather")
                kr, vr = np_merge_kv(ak, av, bk, bv)
                assert np.array_equal(np.asarray(kw), kr), (p, na, nb, flavor)
                assert np.array_equal(np.asarray(vw), vr), (p, na, nb, flavor)
                assert np.array_equal(np.asarray(kw), np.asarray(kg)), (p, na, nb)
                assert np.array_equal(np.asarray(vw), np.asarray(vg)), (p, na, nb)
            else:  # batched kv
                R = 3
                ak = np.sort(rng.integers(-9, 9, (R, na)), axis=1).astype(np.int32)
                bk = np.sort(rng.integers(-9, 9, (R, nb)), axis=1).astype(np.int32)
                av = np.tile(np.arange(na, dtype=np.int32), (R, 1))
                bv = 10_000 + np.tile(np.arange(nb, dtype=np.int32), (R, 1))
                args = (jnp.array(ak), jnp.array(av), jnp.array(bk), jnp.array(bv))
                kw, vw = distributed_merge_kv_batched(*args, mesh=mesh, exchange="window")
                kg, vg = distributed_merge_kv_batched(*args, mesh=mesh, exchange="gather")
                assert np.array_equal(np.asarray(kw), np.asarray(kg)), (p, na, nb)
                assert np.array_equal(np.asarray(vw), np.asarray(vg)), (p, na, nb)
                for r in range(R):
                    kr, vr = np_merge_kv(ak[r], av[r], bk[r], bv[r])
                    assert np.array_equal(np.asarray(kw)[r], kr), (p, r)
                    assert np.array_equal(np.asarray(vw)[r], vr), (p, r)
            # max-window / max-piece bound assertion: the true cut table
            # must respect the static buffer bounds for every device
            a1 = (a if flavor == "float" else ak)
            b1 = (b if flavor == "float" else bk)
            if a1.ndim == 2:
                a_rows, b_rows = list(a1), list(b1)
            else:
                a_rows, b_rows = [a1], [b1]
            seg, W_a, W_b, w_a, w_b = window_bounds(na, nb, p)
            m_a, m_b = -(-na // p), -(-nb // p)
            for ar, br in zip(a_rows, b_rows):
                diags = np.minimum(np.arange(p + 1) * seg, na + nb)
                acut = np_cuts(ar, br, diags)
                bcut = diags - acut
                alen, blen = np.diff(acut), np.diff(bcut)
                assert (alen <= W_a).all() and (blen <= W_b).all(), (p, na, nb)
                # pieces: overlap of each sender shard with each window
                for cuts, m, w in ((acut, m_a, w_a), (bcut, m_b, w_b)):
                    for j in range(p):
                        piece = np.minimum(cuts[1:], (j + 1) * m) - np.maximum(cuts[:-1], j * m)
                        assert (piece <= w).all(), (p, na, nb, j)
        print("ok")
    """)


def test_distributed_sort_combines_and_topk_exchanges():
    """combine="tournament" (incl. the Pallas-kernel rounds of
    local_sort="pallas") matches combine="onepass"; the butterfly top-k
    combine matches the gather tree bit-for-bit; the batched top-k and the
    sampler's backend="distributed" agree with lax.top_k."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed_sort, distributed_topk, distributed_topk_batched
        from repro.serving.sampler import topk_sample
        rng = np.random.default_rng(5)
        x = rng.integers(-1000, 1000, 1024).astype(np.int32)
        x[:4] = np.iinfo(np.int32).max  # sentinel-valued payloads
        ref = np.sort(x)
        P = 8
        outs = {}
        for combine, local_sort in [("onepass", "core"), ("tournament", "core"),
                                    ("tournament", "pallas")]:
            s, cnt, ovf = distributed_sort(jnp.array(x), combine=combine, local_sort=local_sort)
            s, cnt = np.asarray(s), np.asarray(cnt)
            assert not np.asarray(ovf), (combine, local_sort)
            percap = s.shape[0] // P
            got = np.concatenate([s[i*percap:i*percap+cnt[i]] for i in range(P)])
            assert np.array_equal(got, ref), (combine, local_sort)
        # top-k: butterfly == gather == lax.top_k (incl. duplicate values)
        y = rng.integers(-20, 20, 2048).astype(np.int32)
        vb, ib = distributed_topk(jnp.array(y), 16, exchange="butterfly")
        vg, ig = distributed_topk(jnp.array(y), 16, exchange="gather")
        rv, ri = jax.lax.top_k(jnp.array(y), 16)
        assert np.array_equal(np.asarray(vb), np.asarray(rv)) and np.array_equal(np.asarray(ib), np.asarray(ri))
        assert np.array_equal(np.asarray(vb), np.asarray(vg)) and np.array_equal(np.asarray(ib), np.asarray(ig))
        # batched top-k over a vocab-sharded batch + the sampler route
        X = rng.standard_normal((4, 512)).astype(np.float32)
        vb, ib = distributed_topk_batched(jnp.array(X), 8)
        rv, ri = jax.lax.top_k(jnp.array(X), 8)
        assert np.array_equal(np.asarray(vb), np.asarray(rv)) and np.array_equal(np.asarray(ib), np.asarray(ri))
        tok_d = topk_sample(jnp.array(X), jax.random.key(0), k=8, backend="distributed")
        tok_c = topk_sample(jnp.array(X), jax.random.key(0), k=8, backend="core")
        assert np.array_equal(np.asarray(tok_d), np.asarray(tok_c))
        print("ok")
    """, timeout=1200)  # three full distributed sorts incl. interpret-mode
    # Pallas rounds: ~400-580 s on this host, so the default 600 s
    # subprocess cap is flaky on a loaded machine


def test_sharded_train_step_on_debug_mesh():
    """2x2 mesh: jitted train step with FSDP+TP shardings runs and matches
    the unsharded step's loss."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, TrainConfig
        from repro.train.steps import make_train_step, init_train_state
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.specs import state_shardings, batch_shardings
        from repro.parallel.sharding import make_rules, sharding_env
        from repro.configs.base import ShapeConfig

        cfg = get_config("tinyllama-1.1b").reduced()
        tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=10)
        mesh = make_debug_mesh(2, 2)
        rules = make_rules(mesh)
        state = init_train_state(cfg, tcfg, jax.random.key(0))
        _, st_sh = state_shardings(cfg, tcfg, mesh, rules)
        shape = ShapeConfig("t", 32, 4, "train")
        b_sh = batch_shardings(cfg, shape, "train", mesh, rules)
        toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        with sharding_env(mesh, rules):
            step = jax.jit(make_train_step(cfg, tcfg), in_shardings=(st_sh, b_sh),
                           out_shardings=(st_sh, None))
            state_sh = jax.device_put(state, st_sh)
            batch_sh = jax.device_put(batch, b_sh)
            new_state, metrics = step(state_sh, batch_sh)
        sharded_loss = float(metrics["loss"])
        # unsharded reference
        step1 = jax.jit(make_train_step(cfg, tcfg))
        _, m1 = step1(state, batch)
        assert abs(sharded_loss - float(m1["loss"])) < 1e-2, (sharded_loss, float(m1["loss"]))
        print("ok", sharded_loss)
    """, n=8)


def test_ragged_hardening_distributed():
    """PR 2 regressions in one subprocess: non-divisible distributed_merge,
    ragged bucket counts with iinfo.max payloads in distributed_sort, and
    no pad-index leakage from distributed_topk under all--inf shards."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed_merge, distributed_sort, distributed_topk
        rng = np.random.default_rng(7)
        # merge: |A|, |B| not divisible by P=8
        for na, nb in [(513, 511), (700, 37), (5, 1000)]:
            a = np.sort(rng.standard_normal(na)).astype(np.float32)
            b = np.sort(rng.standard_normal(nb)).astype(np.float32)
            out = np.asarray(distributed_merge(jnp.array(a), jnp.array(b)))
            assert out.shape == (na + nb,)
            assert np.allclose(out, np.sort(np.concatenate([a, b]))), (na, nb)
        # sample sort: int payloads equal to the sentinel ride the ragged
        # bucket combine exactly
        M = np.iinfo(np.int32).max
        x = rng.integers(-1000, 1000, 2048).astype(np.int32)
        x[:5] = M
        s, cnt, ovf = distributed_sort(jnp.array(x))
        s, cnt = np.asarray(s), np.asarray(cnt)
        assert not np.asarray(ovf)
        P = 8; percap = s.shape[0] // P
        got = np.concatenate([s[i*percap:i*percap+cnt[i]] for i in range(P)])
        assert (got == np.sort(x)).all()
        # top-k: shards full of -inf logits (keys tie with the pad
        # sentinel) must never surface a pad index
        x = np.full(4096, -np.inf, np.float32)
        x[100] = 1.0; x[3000] = 2.0
        v, i = distributed_topk(jnp.array(x), 16)
        v, i = np.asarray(v), np.asarray(i)
        assert (i >= 0).all(), i
        rv, ri = jax.lax.top_k(jnp.array(x), 16)
        assert np.array_equal(v, np.asarray(rv)) and (i == np.asarray(ri)).all()
        # int shards containing iinfo.min: the flip_desc combine must not
        # wrap them into spurious global maxima
        m = np.iinfo(np.int32).min
        xi = np.full(64, m, np.int32)
        xi[10] = 5; xi[40] = -3
        v, i = distributed_topk(jnp.array(xi), 4)
        rv, ri = jax.lax.top_k(jnp.array(xi), 4)
        assert (np.asarray(v) == np.asarray(rv)).all()
        assert (np.asarray(i) == np.asarray(ri)).all()
        print("ok")
    """)
