"""Multi-device tests for core.distributed — run in a subprocess with 8
fake CPU devices so the main pytest process keeps 1 device (per spec)."""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"


def test_distributed_merge():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed_merge
        rng = np.random.default_rng(1)
        for na, nb in [(512, 512), (768, 256), (256, 768)]:
            a = np.sort(rng.standard_normal(na)).astype(np.float32)
            b = np.sort(rng.standard_normal(nb)).astype(np.float32)
            out = np.asarray(distributed_merge(jnp.array(a), jnp.array(b)))
            assert np.allclose(out, np.sort(np.concatenate([a, b])))
        print("ok")
    """)


def test_distributed_sort():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed_sort
        rng = np.random.default_rng(2)
        x = rng.standard_normal(2048).astype(np.float32)
        s, cnt, ovf = distributed_sort(jnp.array(x))
        s, cnt, ovf = np.asarray(s), np.asarray(cnt), np.asarray(ovf)
        assert not ovf
        P = 8; percap = s.shape[0] // P
        got = np.concatenate([s[i*percap:i*percap+cnt[i]] for i in range(P)])
        assert np.allclose(got, np.sort(x))
        print("ok")
    """)


def test_distributed_topk():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed_topk
        rng = np.random.default_rng(3)
        x = rng.standard_normal(4096).astype(np.float32)
        v, i = distributed_topk(jnp.array(x), 16)
        rv, ri = jax.lax.top_k(jnp.array(x), 16)
        assert np.allclose(np.asarray(v), np.asarray(rv))
        assert (np.asarray(i) == np.asarray(ri)).all()
        print("ok")
    """)


def test_sharded_train_step_on_debug_mesh():
    """2x2 mesh: jitted train step with FSDP+TP shardings runs and matches
    the unsharded step's loss."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, TrainConfig
        from repro.train.steps import make_train_step, init_train_state
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.specs import state_shardings, batch_shardings
        from repro.parallel.sharding import make_rules, sharding_env
        from repro.configs.base import ShapeConfig

        cfg = get_config("tinyllama-1.1b").reduced()
        tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=10)
        mesh = make_debug_mesh(2, 2)
        rules = make_rules(mesh)
        state = init_train_state(cfg, tcfg, jax.random.key(0))
        _, st_sh = state_shardings(cfg, tcfg, mesh, rules)
        shape = ShapeConfig("t", 32, 4, "train")
        b_sh = batch_shardings(cfg, shape, "train", mesh, rules)
        toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        with sharding_env(mesh, rules):
            step = jax.jit(make_train_step(cfg, tcfg), in_shardings=(st_sh, b_sh),
                           out_shardings=(st_sh, None))
            state_sh = jax.device_put(state, st_sh)
            batch_sh = jax.device_put(batch, b_sh)
            new_state, metrics = step(state_sh, batch_sh)
        sharded_loss = float(metrics["loss"])
        # unsharded reference
        step1 = jax.jit(make_train_step(cfg, tcfg))
        _, m1 = step1(state, batch)
        assert abs(sharded_loss - float(m1["loss"])) < 1e-2, (sharded_loss, float(m1["loss"]))
        print("ok", sharded_loss)
    """, n=8)


def test_ragged_hardening_distributed():
    """PR 2 regressions in one subprocess: non-divisible distributed_merge,
    ragged bucket counts with iinfo.max payloads in distributed_sort, and
    no pad-index leakage from distributed_topk under all--inf shards."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed_merge, distributed_sort, distributed_topk
        rng = np.random.default_rng(7)
        # merge: |A|, |B| not divisible by P=8
        for na, nb in [(513, 511), (700, 37), (5, 1000)]:
            a = np.sort(rng.standard_normal(na)).astype(np.float32)
            b = np.sort(rng.standard_normal(nb)).astype(np.float32)
            out = np.asarray(distributed_merge(jnp.array(a), jnp.array(b)))
            assert out.shape == (na + nb,)
            assert np.allclose(out, np.sort(np.concatenate([a, b]))), (na, nb)
        # sample sort: int payloads equal to the sentinel ride the ragged
        # bucket combine exactly
        M = np.iinfo(np.int32).max
        x = rng.integers(-1000, 1000, 2048).astype(np.int32)
        x[:5] = M
        s, cnt, ovf = distributed_sort(jnp.array(x))
        s, cnt = np.asarray(s), np.asarray(cnt)
        assert not np.asarray(ovf)
        P = 8; percap = s.shape[0] // P
        got = np.concatenate([s[i*percap:i*percap+cnt[i]] for i in range(P)])
        assert (got == np.sort(x)).all()
        # top-k: shards full of -inf logits (keys tie with the pad
        # sentinel) must never surface a pad index
        x = np.full(4096, -np.inf, np.float32)
        x[100] = 1.0; x[3000] = 2.0
        v, i = distributed_topk(jnp.array(x), 16)
        v, i = np.asarray(v), np.asarray(i)
        assert (i >= 0).all(), i
        rv, ri = jax.lax.top_k(jnp.array(x), 16)
        assert np.array_equal(v, np.asarray(rv)) and (i == np.asarray(ri)).all()
        # int shards containing iinfo.min: the flip_desc combine must not
        # wrap them into spurious global maxima
        m = np.iinfo(np.int32).min
        xi = np.full(64, m, np.int32)
        xi[10] = 5; xi[40] = -3
        v, i = distributed_topk(jnp.array(xi), 4)
        rv, ri = jax.lax.top_k(jnp.array(xi), 4)
        assert (np.asarray(v) == np.asarray(rv)).all()
        assert (np.asarray(i) == np.asarray(ri)).all()
        print("ok")
    """)
