"""Reusable gradient-check harness for the kernel-path VJP tests.

Two complementary checks:

* :func:`fd_check` — central finite differences in **float64** against
  the VJP of the same (pure-JAX) function.  Validates the *math* of a
  reference route; run it on oracle implementations, which execute fine
  under ``jax.experimental.enable_x64``.
* :func:`vjp_compare` — VJP-vs-VJP between the kernel route and the
  oracle route with an identical random cotangent.  The permutation
  VJPs are exact inverse gathers, so for them the comparison is
  **bit-identical** (``bit=True``); recompute-based backwards (SSM)
  compare under atol/rtol.

Both operate on functions of positional array args and tolerate pytree
outputs; integer/float0 gradient leaves are skipped in comparisons.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def _is_float_leaf(x) -> bool:
    try:
        return jnp.issubdtype(jnp.asarray(x).dtype, jnp.inexact)
    except (TypeError, ValueError):  # float0 zeros etc.
        return False


def random_cotangent(out, seed: int = 0):
    """A fixed pseudo-random cotangent matching ``out``'s pytree/shapes.

    Works on concrete outputs and ``jax.eval_shape`` structs; integer
    output leaves get the ``float0`` cotangent JAX requires.
    """
    leaves, treedef = jax.tree_util.tree_flatten(out)
    cts = []
    for i, leaf in enumerate(leaves):
        shape, dtype = leaf.shape, jnp.dtype(leaf.dtype)
        if jnp.issubdtype(dtype, jnp.inexact):
            rng = np.random.default_rng(seed + i)
            cts.append(jnp.asarray(rng.standard_normal(shape), dtype))
        else:
            cts.append(np.zeros(shape, jax.dtypes.float0))
    return jax.tree_util.tree_unflatten(treedef, cts)


def fd_check(f, args, *, eps: float = 1e-5, rtol: float = 1e-6, atol: float = 1e-8,
             seed: int = 0):
    """Central-difference (f64) vs VJP gradients of ``f`` at ``args``.

    ``f`` maps positional arrays to an array/pytree; the check contracts
    the output with a fixed random cotangent ``u`` so one scalar
    functional ``g(x) = <u, f(x)>`` is differentiated both ways.  All
    float args are promoted to float64 (requires ``f`` be pure JAX —
    oracle routes, not Pallas calls).
    """
    from jax.experimental import enable_x64

    with enable_x64():
        args64 = [
            jnp.asarray(np.asarray(a, np.float64)) if _is_float_leaf(a) else jnp.asarray(a)
            for a in args
        ]
        u = random_cotangent(jax.eval_shape(f, *args64), seed)

        @jax.jit  # FD evaluates 2x per input element: compile once
        def scalar(*a):
            out = f(*a)
            return sum(
                jnp.vdot(jnp.asarray(ct, jnp.float64), jnp.asarray(o).astype(jnp.float64))
                for o, ct in zip(jax.tree.leaves(out), jax.tree.leaves(u))
                if _is_float_leaf(o)
            )

        grads = jax.grad(
            scalar, argnums=tuple(i for i, a in enumerate(args64) if _is_float_leaf(a))
        )(*args64)
        gi = iter(grads)
        for i, a in enumerate(args64):
            if not _is_float_leaf(a):
                continue
            g_ad = np.asarray(next(gi))
            g_fd = np.zeros_like(g_ad)
            flat = np.asarray(a, np.float64).ravel()
            for j in range(flat.size):
                hi, lo = flat.copy(), flat.copy()
                hi[j] += eps
                lo[j] -= eps
                fp = float(scalar(*args64[:i], jnp.asarray(hi.reshape(a.shape)), *args64[i + 1:]))
                fm = float(scalar(*args64[:i], jnp.asarray(lo.reshape(a.shape)), *args64[i + 1:]))
                g_fd.ravel()[j] = (fp - fm) / (2 * eps)
            np.testing.assert_allclose(
                g_ad, g_fd, rtol=rtol, atol=atol,
                err_msg=f"FD-vs-VJP mismatch on arg {i}",
            )


def vjp_grads(f, args, ct=None, seed: int = 0):
    """(primal_out, grads) of ``f`` at ``args`` under cotangent ``ct``."""
    out, pullback = jax.vjp(f, *args)
    if ct is None:
        ct = random_cotangent(out, seed)
    return out, pullback(ct)


def vjp_compare(f_kernel, f_oracle, args, *, bit: bool = True,
                rtol: float = 0.0, atol: float = 0.0, seed: int = 0):
    """Assert kernel-route and oracle-route primals AND grads agree.

    ``bit=True`` (permutation VJPs) demands exact equality; otherwise
    atol/rtol bounds apply (recompute backwards).  Returns the kernel
    grads for extra caller-side assertions.
    """
    out_k, pullback_k = jax.vjp(f_kernel, *args)
    out_o, pullback_o = jax.vjp(f_oracle, *args)
    ct = random_cotangent(out_k, seed)
    for lk, lo in zip(jax.tree.leaves(out_k), jax.tree.leaves(out_o)):
        if bit:
            np.testing.assert_array_equal(np.asarray(lk), np.asarray(lo),
                                          err_msg="primal mismatch kernel vs oracle")
        else:
            np.testing.assert_allclose(
                np.asarray(lk, np.float32), np.asarray(lo, np.float32),
                rtol=rtol, atol=atol, err_msg="primal mismatch kernel vs oracle",
            )
    g_k, g_o = pullback_k(ct), pullback_o(ct)
    for i, (lk, lo) in enumerate(zip(jax.tree.leaves(g_k), jax.tree.leaves(g_o))):
        if not (_is_float_leaf(lk) and _is_float_leaf(lo)):
            continue
        if bit:
            np.testing.assert_array_equal(
                np.asarray(lk), np.asarray(lo),
                err_msg=f"grad leaf {i} not bit-identical kernel vs oracle",
            )
        else:
            np.testing.assert_allclose(
                np.asarray(lk, np.float32), np.asarray(lo, np.float32),
                rtol=rtol, atol=atol, err_msg=f"grad leaf {i} kernel vs oracle",
            )
    return g_k
