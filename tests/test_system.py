"""End-to-end system tests: train driver (with failure injection), loss
convergence, and launch-layer plumbing that doesn't need 512 devices."""

import subprocess
import sys
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import SHAPES, TrainConfig, get_config, list_archs
from repro.data.pipeline import PipelineConfig, SyntheticLMPipeline
from repro.train.steps import init_train_state, make_train_step

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_overfit_single_batch():
    cfg = get_config("tinyllama-1.1b").reduced()
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=2, total_steps=40)
    state = init_train_state(cfg, tcfg, jax.random.key(0))
    step = jax.jit(make_train_step(cfg, tcfg))
    toks = jax.random.randint(jax.random.key(1), (4, 17), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    losses = []
    for _ in range(30):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.5 * losses[0]
    assert np.isfinite(losses).all()


def test_grad_compression_still_learns():
    cfg = get_config("tinyllama-1.1b").reduced()
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=2, total_steps=40,
                       grad_compression="int8")
    state = init_train_state(cfg, tcfg, jax.random.key(0))
    step = jax.jit(make_train_step(cfg, tcfg))
    toks = jax.random.randint(jax.random.key(1), (4, 17), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    losses = []
    for _ in range(30):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.6 * losses[0]


def test_train_driver_with_failure_injection(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "tinyllama-1.1b",
         "--steps", "25", "--batch", "2", "--seq", "16", "--ckpt-every", "10",
         "--inject-failure-at", "13", "--ckpt-dir", str(tmp_path)],
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")},
        capture_output=True, text=True, timeout=560,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "[recovery] restored step 10" in proc.stdout
    assert "done at step 25" in proc.stdout


def test_serve_driver(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "tinyllama-1.1b",
         "--requests", "3", "--batch", "2", "--max-new", "3"],
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")},
        capture_output=True, text=True, timeout=560,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "served 3 requests" in proc.stdout


def test_all_archs_registered_with_shapes():
    archs = list_archs()
    assert len(archs) == 10
    assert len(SHAPES) == 4
    for a in archs:
        cfg = get_config(a)
        assert cfg.n_params() > 0
        r = cfg.reduced()
        assert r.d_model == 64


def test_input_specs_cover_all_cells():
    from repro.configs import SHAPES_BY_NAME, shape_applicable
    from repro.launch.specs import input_specs

    for a in list_archs():
        cfg = get_config(a)
        for s in SHAPES:
            ok, _ = shape_applicable(cfg, s)
            if not ok:
                continue
            specs = input_specs(cfg, s)
            leaves = jax.tree.leaves(specs)
            assert leaves, (a, s.name)
            for l in leaves:
                assert isinstance(l, jax.ShapeDtypeStruct)
