"""Fault tolerance, checkpointing, data pipeline, optimizer unit tests."""

import os
import shutil

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import TrainConfig, get_config
from repro.data.pipeline import PipelineConfig, SyntheticLMPipeline
from repro.optim.adamw import adamw_update, cosine_schedule, init_opt_state
from repro.parallel import compression
from repro.runtime.fault_tolerance import (
    HeartbeatMonitor,
    StragglerMonitor,
    TrainLoopSupervisor,
    plan_elastic_mesh,
)
from repro.train.steps import init_train_state, make_train_step


# --- checkpoint ------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"step": jnp.int32(7), "params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"m": {"w": jnp.ones((2, 3))}}}
    mgr.save(7, state, blocking=True)
    like = jax.eval_shape(lambda: state)
    restored = mgr.restore(like)
    assert int(restored["step"]) == 7
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.arange(6.0).reshape(2, 3))


def test_checkpoint_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        mgr.save(s, state, blocking=True)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = {"x": jnp.arange(10.0)}
    mgr.save(5, state, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_atomicity_partial_write_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = {"x": jnp.arange(4.0)}
    mgr.save(1, state, blocking=True)
    # simulate a crashed writer: stale .tmp directory with garbage
    os.makedirs(tmp_path / "step_9.tmp")
    (tmp_path / "step_9.tmp" / "arrays.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 1  # tmp dir not considered


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": jnp.zeros((2, 2))}, blocking=True)
    with pytest.raises(ValueError):
        mgr.restore(jax.eval_shape(lambda: {"x": jnp.zeros((3, 3))}))


# --- fault tolerance -------------------------------------------------------

def test_heartbeat_monitor():
    t = {"now": 0.0}
    mon = HeartbeatMonitor(num_hosts=3, timeout=10, clock=lambda: t["now"])
    t["now"] = 5.0
    mon.beat(0)
    mon.beat(1)
    t["now"] = 12.0
    assert mon.dead_hosts() == [2]
    assert not mon.healthy()


def test_straggler_monitor():
    mon = StragglerMonitor(window=20, factor=2.0, patience=3)
    for _ in range(10):
        assert not mon.record(1.0)
    assert mon.record(5.0)
    assert mon.record(5.0)
    assert not mon.should_remesh()
    assert mon.record(5.0)
    assert mon.should_remesh()


def test_plan_elastic_mesh():
    shape, axes = plan_elastic_mesh(512, 16)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
    # lost a pod's worth of chips -> single-pod mesh
    shape, axes = plan_elastic_mesh(300, 16)
    assert shape == (16, 16) and axes == ("data", "model")
    # heavy loss -> shrink data axis to a power of two
    shape, axes = plan_elastic_mesh(100, 16)
    assert shape == (4, 16)
    with pytest.raises(ValueError):
        plan_elastic_mesh(8, 16)


def test_supervisor_restart_resumes_from_checkpoint():
    calls = {"saves": [], "restores": 0}
    progressed = []

    def step_fn(step):
        if step == 7 and calls["restores"] == 0:
            raise RuntimeError("boom")
        progressed.append(step)

    def save_fn(step):
        calls["saves"].append(step)

    def restore_fn():
        calls["restores"] += 1
        return max([s for s in calls["saves"]], default=0)

    sup = TrainLoopSupervisor(checkpoint_every=5)
    final = sup.run(0, 10, step_fn, save_fn, restore_fn)
    assert final == 10
    assert calls["restores"] == 1
    assert 7 in progressed  # the failed step was replayed after restore


def test_train_restart_bitwise_reproducible(tmp_path):
    """Crash + restore + deterministic data => identical final state."""
    cfg = get_config("tinyllama-1.1b").reduced()
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=8)
    pipe = SyntheticLMPipeline(cfg, 2, 16, PipelineConfig(seed=0))
    step_fn = jax.jit(make_train_step(cfg, tcfg))

    def run(with_crash: bool):
        mgr = CheckpointManager(str(tmp_path / ("a" if with_crash else "b")))
        state = init_train_state(cfg, tcfg, jax.random.key(0))
        s = 0
        while s < 6:
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
            state, _ = step_fn(state, batch)
            s += 1
            if s == 3:
                mgr.save(s, state, blocking=True)
                if with_crash:
                    # lose the in-memory state, restore from disk
                    state = mgr.restore(jax.eval_shape(lambda: state))
        return state

    s1 = run(False)
    s2 = run(True)
    for l1, l2 in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


# --- data pipeline ----------------------------------------------------------

def test_pipeline_deterministic_and_restartable():
    cfg = get_config("tinyllama-1.1b").reduced()
    p1 = SyntheticLMPipeline(cfg, 4, 32, PipelineConfig(seed=1))
    p2 = SyntheticLMPipeline(cfg, 4, 32, PipelineConfig(seed=1))
    b1 = p1.batch_at(17)
    b2 = p2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = p1.batch_at(18)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_pipeline_packing_reduces_padding():
    cfg = get_config("tinyllama-1.1b").reduced()
    pk = SyntheticLMPipeline(cfg, 4, 256, PipelineConfig(seed=2, pack=True, mean_doc_len=32))
    un = SyntheticLMPipeline(cfg, 4, 256, PipelineConfig(seed=2, pack=False))
    packed = pk.batch_at(0)
    frac_pad = float((packed["labels"] < 0).mean())
    assert frac_pad < 0.25, frac_pad
    assert (un.batch_at(0)["labels"] >= 0).all()


def test_pipeline_host_sharding_differs():
    cfg = get_config("tinyllama-1.1b").reduced()
    h0 = SyntheticLMPipeline(cfg, 2, 32, PipelineConfig(seed=1, host_id=0, num_hosts=2))
    h1 = SyntheticLMPipeline(cfg, 2, 32, PipelineConfig(seed=1, host_id=1, num_hosts=2))
    assert not np.array_equal(h0.batch_at(0)["tokens"], h1.batch_at(0)["tokens"])


# --- optimizer / compression -------------------------------------------------

def test_adamw_matches_closed_form_single_param():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=10,
                       weight_decay=0.0, grad_clip=1e9)
    params = {"w": jnp.array([1.0, -2.0])}
    grads = {"w": jnp.array([0.5, 0.5])}
    opt = init_opt_state(params)
    new_p, new_opt, _ = adamw_update(tcfg, params, grads, opt, jnp.int32(0))
    lr = float(cosine_schedule(tcfg, jnp.int32(0)))
    m = 0.1 * 0.5 / (1 - 0.9)
    v = 0.05 * 0.25 / (1 - 0.95)
    expected = 1.0 - lr * (m / (np.sqrt(v) + tcfg.eps))
    np.testing.assert_allclose(float(new_p["w"][0]), expected, rtol=1e-5)


def test_grad_clip_effective():
    tcfg = TrainConfig(grad_clip=1.0)
    params = {"w": jnp.zeros(4)}
    grads = {"w": jnp.full(4, 100.0)}
    opt = init_opt_state(params)
    _, _, metrics = adamw_update(tcfg, params, grads, opt, jnp.int32(0))
    assert float(metrics["grad_norm"]) > 100


def test_compression_error_feedback_unbiased():
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal(1000), jnp.float32)}
    err = compression.init_error_state(g)
    total_sent = jnp.zeros(1000)
    cur_err = err["w"]
    for _ in range(50):
        comp, new_err = compression.compress_grads(g, {"w": cur_err}, "topk", 0.05)
        total_sent = total_sent + comp["w"]
        cur_err = new_err["w"]
    # cumulative transmitted + residual == cumulative gradient (exactness of EF)
    np.testing.assert_allclose(
        np.asarray(total_sent + cur_err), np.asarray(g["w"] * 50), rtol=1e-4, atol=1e-4
    )


def test_int8_compression_bounded_error():
    g = {"w": jnp.asarray(np.random.default_rng(1).standard_normal(512), jnp.float32)}
    err = compression.init_error_state(g)
    comp, new_err = compression.compress_grads(g, err, "int8", 0.0)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127
    assert float(jnp.max(jnp.abs(comp["w"] - g["w"]))) <= scale * 0.5 + 1e-6
