# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
import sys


def main() -> None:
    from benchmarks.bench_merge import (
        bench_load_balance,
        bench_merge_throughput,
        bench_moe_dispatch,
        bench_partition_cost,
        bench_segmented_vs_regular,
        bench_sort,
    )

    rows = []
    for bench in (
        bench_merge_throughput,
        bench_partition_cost,
        bench_load_balance,
        bench_segmented_vs_regular,
        bench_sort,
        bench_moe_dispatch,
    ):
        print(f"# running {bench.__name__} ...", file=sys.stderr, flush=True)
        bench(rows)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")


if __name__ == "__main__":
    main()
