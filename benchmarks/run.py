"""One function per paper table. Prints ``name,us_per_call,derived`` CSV.

``--smoke`` shrinks every benchmark's problem size so the full sweep
finishes quickly (CI smoke: ``make bench-smoke``).
``--only substr`` runs just the benchmarks whose name contains substr.
``--json PATH`` additionally writes the rows as JSON — ``make ci`` uses
this to record the per-PR perf trajectory (BENCH_<n>.json).
"""
import argparse
import json
import os
import sys

# allow `python benchmarks/run.py` from the repo root (or anywhere):
# the repo root for the `benchmarks` package, `src` for `repro` itself
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true", help="small sizes, fast sweep")
    parser.add_argument("--only", default="", help="run only benchmarks whose name contains this")
    parser.add_argument("--json", default="", help="also write rows as JSON to this path")
    args = parser.parse_args()

    from benchmarks.bench_merge import (
        bench_batched_merge,
        bench_load_balance,
        bench_merge_throughput,
        bench_moe_dispatch,
        bench_partition_cost,
        bench_ragged_merge,
        bench_segmented_vs_regular,
        bench_sort,
    )
    from benchmarks.bench_distributed import bench_distributed
    from benchmarks.bench_serving import bench_serving
    from benchmarks.bench_tile_engine import bench_tile_engine
    from benchmarks._timing import stopwatch

    rows = []
    with stopwatch() as sw:
        for bench in (
            bench_merge_throughput,
            bench_tile_engine,
            bench_distributed,
            bench_batched_merge,
            bench_ragged_merge,
            bench_partition_cost,
            bench_load_balance,
            bench_segmented_vs_regular,
            bench_sort,
            bench_moe_dispatch,
            bench_serving,
        ):
            if args.only and args.only not in bench.__name__:
                continue
            print(f"# running {bench.__name__} ...", file=sys.stderr, flush=True)
            bench(rows, smoke=args.smoke)
    total_s = sw.seconds
    print(f"# total {total_s:.1f}s", file=sys.stderr)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")

    # Guarded-dispatch health: a benchmark run that silently degraded
    # (e.g. every pallas launch fell back to core) would report numbers
    # for the wrong code path — surface the counters and fail loudly.
    from repro.runtime import faults as _faults
    from repro.runtime import resilience as _res

    health = _res.health_summary()
    totals = health["totals"]
    print(
        f"# health: calls={totals['calls']} fallbacks={totals['fallbacks']} "
        f"preflight_rejects={totals['precondition_rejects']} "
        f"launch_failures={totals['launch_failures']} "
        f"verify_failures={totals['verify_failures']} "
        f"exhausted={totals['exhausted']}",
        file=sys.stderr,
    )
    for op, rec in sorted(health.items()):
        if op != "totals" and rec["fallbacks"]:
            print(f"# health[{op}]: fallback_edges={rec['fallback_edges']}", file=sys.stderr)
    if totals["fallbacks"] and not _faults.active():
        print(
            f"# health: FAIL — {totals['fallbacks']} fallback(s) taken with no "
            f"fault plan active; benchmark numbers describe degraded paths",
            file=sys.stderr,
        )
        sys.exit(1)

    if args.json:
        from repro.telemetry import get_telemetry, summary as telemetry_summary

        payload = {
            "smoke": bool(args.smoke),
            "only": args.only,
            "total_seconds": round(total_s, 1),
            "health": health,
            "telemetry": telemetry_summary(get_telemetry()),
            "rows": rows,
        }
        # record the perf-gate anchor rows explicitly so a snapshot is
        # self-describing (tools/bench_diff.py diffs these across PRs)
        from tools.bench_diff import anchor_values

        payload["anchors"] = {
            name: {"metric": metric, "value": value}
            for name, (metric, value) in sorted(anchor_values(payload).items())
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
