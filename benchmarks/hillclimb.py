"""Perf hillclimb driver: measure one (arch x shape) cell under a named
variant (a set of ModelConfig/TrainConfig overrides) and record the
roofline delta vs baseline.

    PYTHONPATH=src:. python -m benchmarks.hillclimb --arch tinyllama-1.1b \
        --shape train_4k --variant blockwise_attn

Variants are defined in VARIANTS below; each is one hypothesis->change
pair from EXPERIMENTS.md §Perf.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json


VARIANTS = {
    # H: materializing S^2 f32 scores dominates train bytes; online-softmax
    # blockwise attention streams them through a chunk-sized buffer.
    "blockwise_attn": dict(model=dict(train_attn_blockwise=True)),
    # H: remat recomputes the whole layer; flops fall if we disable it
    # (memory rises — the trade is visible in temp_bytes).
    "no_remat": dict(model=dict(remat=False)),
    # H: MoE dispatch buffers scale with capacity_factor; 1.0 halves the
    # (B,E,C,d) einsum traffic at the cost of more drops.
    "cap_1_0": dict(model=dict(capacity_factor=1.0)),
    # H: cumsum dispatch trades the merge-path sort for an O(N*E) one-hot
    # cumsum — compare both directions on the MoE cell.
    "cumsum_dispatch": dict(model=dict(moe_dispatch="cumsum")),
    # H: a larger SSM chunk reduces scan trips (less loop overhead, more
    # live memory).
    "ssm_chunk_512": dict(model=dict(ssm_chunk=512)),
    "ssm_chunk_32": dict(model=dict(ssm_chunk=32)),
    # H: the associative scan's (B,S,di,st) element tensors dominate SSM
    # bytes; scanning in bf16 halves them (carry/output still f32-accumulated
    # at the layer boundary).
    "ssm_bf16_scan": dict(model=dict(ssm_scan_dtype="bfloat16")),
    "ssm_bf16_scan_chunk32": dict(model=dict(ssm_scan_dtype="bfloat16", ssm_chunk=32)),
    # H: gradient accumulation (4 microbatches) shrinks activation temps
    # ~4x at the same math.
    "microbatch_4": dict(train=dict(microbatch=4)),
    # H: int8 pod-gradient compression cuts cross-pod wire bytes ~4x.
    "int8_compress": dict(train=dict(grad_compression="int8")),
    # H: larger attention chunks amortize the online-softmax rescale
    # (fewer scan trips, bigger live buffer).
    "attn_chunk_4096": dict(model=dict(attn_chunk=4096)),
    "attn_chunk_2048": dict(model=dict(attn_chunk=2048)),
    # H: save-dots remat recomputes only cheap elementwise ops — flops near
    # no_remat, temp memory near full remat.
    "remat_dots": dict(model=dict(remat_policy="dots")),
    # best-combo variants (per-cell winners combined)
    "combo_tinyllama": dict(model=dict(train_attn_blockwise=True, remat_policy="dots")),
    "combo_moonshot": dict(model=dict(moe_dispatch="cumsum", capacity_factor=1.0,
                                      remat_policy="dots")),
    # deployable optima: the best measured throughput config that also FITS
    # a 16 GB v5e (microbatching for capacity + the cell's throughput wins)
    "deploy_tinyllama": dict(model=dict(train_attn_blockwise=True),
                             train=dict(microbatch=4)),
    "deploy_moonshot": dict(model=dict(moe_dispatch="cumsum", capacity_factor=1.0),
                            train=dict(microbatch=4)),
    # H: MQA (kv=1) wk/wv tensor-sharding splits one head across 16 devices;
    # XLA reshards K/V via collective-permutes (34 GB/dev measured).
    # Replicating the 128-wide kv output removes them.
    "replicate_kv": dict(model=dict(replicate_kv_proj=True)),
}


def run(arch: str, shape: str, variant: str, multi_pod: bool, out_dir: str):
    from repro.configs import TrainConfig, get_config
    from repro.launch.dryrun import cell_filename, lower_cell

    from benchmarks._timing import stopwatch

    overrides = VARIANTS[variant] if variant != "baseline" else {}
    cfg = get_config(arch)
    if overrides.get("model"):
        cfg = dataclasses.replace(cfg, **overrides["model"])
    tcfg = TrainConfig(**overrides.get("train", {}))
    with stopwatch() as sw:
        record, _ = lower_cell(arch, shape, multi_pod, tcfg=tcfg, cfg_override=cfg)
    record["variant"] = variant
    record["wall_s"] = round(sw.seconds, 3)
    os.makedirs(out_dir, exist_ok=True)
    fname = os.path.join(out_dir, f"{variant}__" + cell_filename(arch, shape, multi_pod))
    with open(fname, "w") as f:
        json.dump(record, f, indent=1)
    if record["status"] == "ok":
        r = record["roofline"]
        print(f"{arch} x {shape} [{variant}]: "
              f"t_comp {r['t_compute_s']*1e3:.1f}ms t_mem {r['t_memory_s']*1e3:.1f}ms "
              f"t_coll {r['t_collective_s']*1e3:.1f}ms -> {r['bottleneck']} "
              f"(useful {r['useful_flops_fraction']:.2f}, mfu_bound {r['mfu_bound']*100:.1f}%)")
    else:
        print(f"{arch} x {shape} [{variant}]: {record['status']}: {record.get('error','')[:400]}")
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline"] + sorted(VARIANTS))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()
    run(args.arch, args.shape, args.variant, args.multi_pod, args.out)


if __name__ == "__main__":
    main()
