"""Assemble EXPERIMENTS.md roofline tables from experiments/dryrun/*.json.

    PYTHONPATH=src:. python -m benchmarks.roofline_report [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_t(sec):
    if sec is None:
        return "-"
    if sec >= 1:
        return f"{sec:.2f} s"
    return f"{sec*1e3:.2f} ms"


def fmt_b(x):
    if x is None:
        return "-"
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if abs(x) >= div:
            return f"{x/div:.2f} {unit}"
    return f"{x:.0f} B"


def load(dirname):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def table(recs, multi_pod: bool) -> str:
    rows = [
        "| arch | shape | status | FLOPs/dev | bytes/dev | wire/dev | t_comp | t_mem | t_coll | bottleneck | useful | MFU-bound |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if bool(r.get("multi_pod")) != multi_pod:
            continue
        arch, shape = r["arch"], r["shape"]
        if r["status"] == "skipped":
            rows.append(f"| {arch} | {shape} | skipped | - | - | - | - | - | - | - | - | - |")
            continue
        if r["status"] == "error":
            rows.append(f"| {arch} | {shape} | ERROR | - | - | - | - | - | - | - | - | - |")
            continue
        ro = r["roofline"]
        rows.append(
            f"| {arch} | {shape} | ok "
            f"| {ro['flops_per_device']:.2e} | {fmt_b(ro['bytes_per_device'])} "
            f"| {fmt_b(ro['wire_bytes_per_device'])} "
            f"| {fmt_t(ro['t_compute_s'])} | {fmt_t(ro['t_memory_s'])} | {fmt_t(ro['t_collective_s'])} "
            f"| **{ro['bottleneck']}** | {ro['useful_flops_fraction']:.2f} "
            f"| {ro['mfu_bound']*100:.1f}% |"
        )
    return "\n".join(rows)


def memory_table(recs) -> str:
    rows = [
        "| arch | shape | mesh | args/dev | temps/dev | fits 16GB v5e? |",
        "|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            continue
        m = r.get("memory_analysis", {})
        a, t = m.get("argument_bytes"), m.get("temp_bytes")
        fits = "-"
        if a is not None and t is not None:
            fits = "yes" if (a + t) < 16e9 else "**NO**"
        pod = "2x16x16" if r["multi_pod"] else "16x16"
        rows.append(f"| {r['arch']} | {r['shape']} | {pod} | {fmt_b(a)} | {fmt_b(t)} | {fits} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)
    n_ok = sum(r["status"] == "ok" for r in recs)
    n_skip = sum(r["status"] == "skipped" for r in recs)
    n_err = sum(r["status"] == "error" for r in recs)
    print(f"## Roofline table — single pod (16x16 = 256 chips)\n")
    print(table(recs, False))
    print(f"\n## Roofline table — multi-pod (2x16x16 = 512 chips)\n")
    print(table(recs, True))
    print(f"\n## Memory analysis (per device)\n")
    print(memory_table(recs))
    print(f"\ncells: {n_ok} ok, {n_skip} skipped, {n_err} error")


if __name__ == "__main__":
    main()
