"""Distributed Merge Path benchmark: gather vs window exchange on a forced
8-device host mesh.

The interesting number is **bytes moved per device**, not wall-clock: on
the host-emulated mesh every "collective" is a memcpy, so wall time mostly
measures trace/compile overhead, while the bytes column is exactly what an
ICI would carry.  Per-device exchanged bytes come from
``repro.core.distributed.exchange_bytes``:

* ``gather``: every device receives the other P-1 shards — O(N).
* ``window`` payload: each device receives exactly its output segment's
  windows (``alen + blen = seg = N/P`` elements) plus the collective
  bisection's probe traffic — O(N/P).
* ``window`` wire (padded): what the dense static-shape ``all_to_all``
  ships with pieces padded to the provable max-piece bound; a
  ``ragged_all_to_all`` backend would collapse this to the payload number.

Because the main process must keep a single device (see tests/conftest),
the measurement runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and reports JSON on
stdout.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from typing import Dict, List

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_INNER = """
import json, sys
import numpy as np, jax, jax.numpy as jnp
from repro.core import distributed_merge, distributed_sort
from repro.core.distributed import exchange_bytes
from benchmarks._timing import timeit
from repro.telemetry import get_telemetry
import repro.runtime.faults as faults
import repro.runtime.resilience as res

P = 8
n = int(sys.argv[1])
iters = int(sys.argv[2])
rng = np.random.default_rng(0)
na = nb = n // 2
a = jnp.asarray(np.sort(rng.standard_normal(na)).astype(np.float32))
b = jnp.asarray(np.sort(rng.standard_normal(nb)).astype(np.float32))
rows = []

eb = exchange_bytes(na, nb, P, 4)
ref = None
for exchange in ("gather", "window"):
    us = timeit(
        lambda: distributed_merge(a, b, exchange=exchange),
        iters=iters, warmup=1,
        label=f"distributed/merge_{exchange}_n{n}_p{P}",
    )
    out = np.asarray(distributed_merge(a, b, exchange=exchange))
    if ref is None:
        ref = out
    assert np.array_equal(out, ref), "exchange flavors disagree"
    bytes_dev = eb[exchange] if exchange == "gather" else eb["window_payload"]
    derived = (
        f"bytes/device={bytes_dev} total_bytes={(na + nb) * 4}"
        + ("" if exchange == "gather" else f" wire_padded={eb['window_wire_padded']}")
    )
    rows.append({
        "name": f"distributed/merge_{exchange}_n{n}_p{P}",
        "us_per_call": us,
        "derived": derived,
    })

x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
for combine in ("onepass", "tournament"):
    us = timeit(
        lambda: distributed_sort(x, combine=combine)[0],
        iters=iters, warmup=1,
        label=f"distributed/sort_{combine}_n{n}_p{P}",
    )
    rows.append({
        "name": f"distributed/sort_{combine}_n{n}_p{P}",
        "us_per_call": us,
        "derived": "one all_to_all bucket round",
    })

hs = res.health_summary()
assert faults.active() or hs["totals"]["fallbacks"] == 0, hs
print(json.dumps({"rows": rows, "telemetry": get_telemetry().snapshot()}))
"""


def bench_distributed(rows: List[Dict], smoke: bool = False) -> None:
    """Run the distributed merge/sort benchmark in an 8-device subprocess."""
    n = 1 << 12 if smoke else 1 << 16
    iters = 2 if smoke else 5
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    # src for repro, repo root for benchmarks._timing
    env["PYTHONPATH"] = os.path.join(_ROOT, "src") + os.pathsep + _ROOT
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_INNER), str(n), str(iters)],
        env=env,
        capture_output=True,
        text=True,
        timeout=1200,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench_distributed subprocess failed:\n{proc.stdout}\n{proc.stderr}"
        )
    payload = json.loads(proc.stdout.strip().splitlines()[-1])
    rows.extend(payload["rows"])
    # fold the subprocess's counters/gauges/histograms (per-device window
    # sizes, exchange bytes, balance ratio, bench percentiles) into this
    # process's registry so run.py's telemetry summary carries them
    from repro.telemetry import get_telemetry

    get_telemetry().merge_snapshot(payload["telemetry"])
