"""Shared benchmark timing: the one ``timeit`` (and the one stopwatch).

Replaces the four copy-pasted ``timeit`` helpers that used to live in
``bench_merge.py`` / ``bench_distributed.py`` / ``bench_tile_engine.py``
/ ``hillclimb.py``.  Every sample lands in a telemetry histogram
(``bench/<label>``) in the active registry, so each bench row can report
exact p50/p95/p99 — not just the median — and ``benchmarks/run.py``
folds the full distribution into the ``BENCH_*.json`` telemetry block.

This file and ``src/repro/telemetry/`` are the only places allowed to
touch ``time.perf_counter`` directly (lint rule L007).
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Optional

import jax

from repro.telemetry import get_telemetry


def timeit(fn, *args, iters: int = 5, warmup: int = 2, label: Optional[str] = None) -> float:
    """Median wall-clock microseconds per call of ``fn(*args)``.

    Blocks on device completion each iteration.  When ``label`` is given,
    every sample is recorded into the ``bench/<label>`` histogram of the
    active telemetry registry (exact percentiles for the bench summary).
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    if label is not None:
        hist = get_telemetry().histogram(f"bench/{label}")
        for s in samples:
            hist.record(s)
    samples.sort()
    mid = len(samples) // 2
    if len(samples) % 2:
        return samples[mid]
    return 0.5 * (samples[mid - 1] + samples[mid])


class _Stopwatch:
    __slots__ = ("seconds",)

    def __init__(self) -> None:
        self.seconds = 0.0


@contextmanager
def stopwatch():
    """Wall-clock a block: ``with stopwatch() as sw: ...; sw.seconds``."""
    sw = _Stopwatch()
    t0 = time.perf_counter()
    try:
        yield sw
    finally:
        sw.seconds = time.perf_counter() - t0
