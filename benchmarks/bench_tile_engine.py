"""Tile-engine benchmark: (T, T) merge matrix vs the hierarchical engine.

The acceptance measurement for the two-level tile engine (PR 3): per
tile size T, the same Pallas SPM kernel runs with

* ``engine="matrix"`` — the original single-level body: a full (T, T)
  merge matrix + (T, T) one-hot rank application, O(T^2) per tile;
* ``engine="hier"``  — the two-level body: level-2 sub-diagonal
  bisection into S-wide leaves, (S, S) leaf merge matrices, O(T) gather
  apply — O(T*S + T log T) per tile.

Both engines produce bit-identical merges (asserted by
``tests/test_tile_engine.py``); this file records the speed gap for keys
and key-value merges at T in {128, 512, 1024} plus the derived
``speedup`` rows that BENCH_3.json carries forward.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks._timing import timeit
from benchmarks.bench_merge import _sorted_pair

TILES = (128, 512, 1024)
LEAF = 32


def bench_tile_engine(rows: List[Dict], smoke: bool = False) -> None:
    from repro.kernels.merge_path import merge_kv_pallas, merge_pallas

    n = (1 << 13) if smoke else (1 << 15)  # per side
    iters, warmup = (2, 1) if smoke else (4, 2)
    a, b = _sorted_pair(n, seed=11)
    av = jnp.arange(n, dtype=jnp.float32)
    bv = jnp.arange(n, dtype=jnp.float32) + n
    for tile in TILES:
        us = {}
        for engine in ("matrix", "hier"):
            fn = jax.jit(
                lambda x, y, t=tile, e=engine: merge_pallas(x, y, tile=t, leaf=LEAF, engine=e)
            )
            us[engine] = timeit(
                fn, a, b, iters=iters, warmup=warmup,
                label=f"tile_engine/keys_{engine}/T={tile}",
            )
            rows.append({
                "name": f"tile_engine/keys_{engine}/T={tile}",
                "us_per_call": us[engine],
                "derived": f"{2*n/us[engine]:.1f} Melem/s",
            })
        rows.append({
            "name": f"tile_engine/keys_speedup/T={tile}",
            "us_per_call": 0.0,
            "derived": f"{us['matrix']/us['hier']:.2f}x (hier S={LEAF} vs matrix)",
        })
        us = {}
        for engine in ("matrix", "hier"):
            fn = jax.jit(
                lambda ak, xv, bk, yv, t=tile, e=engine: merge_kv_pallas(
                    ak, xv, bk, yv, tile=t, leaf=LEAF, engine=e
                )
            )
            us[engine] = timeit(
                fn, a, av, b, bv, iters=iters, warmup=warmup,
                label=f"tile_engine/kv_{engine}/T={tile}",
            )
            rows.append({
                "name": f"tile_engine/kv_{engine}/T={tile}",
                "us_per_call": us[engine],
                "derived": f"{2*n/us[engine]:.1f} Melem/s",
            })
        rows.append({
            "name": f"tile_engine/kv_speedup/T={tile}",
            "us_per_call": 0.0,
            "derived": f"{us['matrix']/us['hier']:.2f}x (hier S={LEAF} vs matrix)",
        })
