"""Serving-engine decode microbenchmark: the ROADMAP's tokens/s,
per-tick latency percentiles, and slot-occupancy numbers.

A small synthetic closed workload (every request submitted up front —
the smallest stand-in for an open-loop stream that still exercises slot
refill and lockstep decode) runs through ``ServingEngine.run_until_done``
on the reduced tinyllama config.  The interesting columns come from the
engine's own telemetry: decode-tick wall p50/p95/p99
(``serving.tick_wall_us``), mean slot occupancy, and ticks-to-first-token
— all folded into the ``BENCH_*.json`` telemetry block by ``run.py``.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np


def bench_serving(rows: List[Dict], smoke: bool = False) -> None:
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving.engine import Request, ServingEngine

    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_params(cfg, jax.random.key(0))
    batch, max_seq = (2, 32) if smoke else (4, 64)
    n_req, new_tokens = (3, 2) if smoke else (8, 4)
    eng = ServingEngine(cfg, params, batch=batch, max_seq=max_seq)
    rng = np.random.default_rng(0)
    for i in range(n_req):
        eng.submit(
            Request(
                uid=i,
                prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                max_new_tokens=new_tokens,
                temperature=0.0,
            )
        )
    rep = eng.run_until_done()
    assert rep.ok(), f"serving bench degraded: {rep}"

    tick = rep.telemetry["tick_wall_us"]
    occ = rep.telemetry["slot_occupancy"]
    ttft = rep.telemetry["ticks_to_first_token"]
    tokens = rep.completed * new_tokens
    tok_per_s = tokens / (tick["mean"] * tick["count"] / 1e6) if tick["count"] else 0.0
    rows.append({
        "name": f"serving/decode_tick/B={batch}/req={n_req}",
        "us_per_call": tick.get("p50", 0.0),
        "derived": (
            f"p50={tick.get('p50', 0):.0f}us p95={tick.get('p95', 0):.0f}us "
            f"p99={tick.get('p99', 0):.0f}us over {tick['count']} ticks"
        ),
    })
    rows.append({
        "name": f"serving/throughput/B={batch}/req={n_req}",
        "us_per_call": 0.0,
        "derived": f"{tok_per_s:.1f} tok/s ({tokens} tokens, {rep.ticks} ticks)",
    })
    rows.append({
        "name": f"serving/slot_occupancy/B={batch}/req={n_req}",
        "us_per_call": 0.0,
        "derived": (
            f"mean={occ.get('mean', 0):.2f}/{batch} slots, "
            f"ticks_to_first_token p50={ttft.get('p50', 0):.0f}"
        ),
    })
