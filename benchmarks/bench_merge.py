"""Benchmarks mirroring the paper's tables/figures, adapted to this
environment (single-CPU host; TPU numbers come from the dry-run roofline).

Paper artifact -> benchmark:
* Fig 4/5 (speedup vs threads)      -> bench_load_balance (Corollary 7: the
  partition gives *exactly* equal per-lane work, the paper's precondition
  for linear speedup; we measure per-lane work spread directly) and
  bench_partition_cost (the O(p log N) partition stage, Table 1 col 1).
* Table 1 (cache misses)            -> bench_segmented_vs_regular (SPM vs
  flat merge wall time on CPU, where the host cache plays the role the
  paper's L2/L3 plays).
* merging throughput                -> bench_merge_throughput (Pallas SPM
  kernel vs XLA sort oracle vs flat rank-merge).
* batched merging (§6 "building
  block for other functions")       -> bench_batched_merge (one 2-D-grid
  kernel launch for B merges vs a loop of pairwise 1-D launches, plus the
  fused pure-JAX batched pass vs vmapped pairwise).
* merge-sort                        -> bench_sort.
* framework integration (DESIGN §3) -> bench_moe_dispatch (merge-path vs
  cumsum dispatch inside the MoE layer).

Every bench takes ``smoke=True`` to shrink problem sizes so the whole
suite finishes in well under a minute (``benchmarks/run.py --smoke``,
wired to ``make bench-smoke``).
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks._timing import timeit


def _sorted_pair(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    a = np.sort(rng.standard_normal(n)).astype(np.float32)
    b = np.sort(rng.standard_normal(n)).astype(np.float32)
    return jnp.asarray(a), jnp.asarray(b)


def _sorted_rows(b: int, n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    x = np.sort(rng.standard_normal((b, n)), axis=1).astype(np.float32)
    y = np.sort(rng.standard_normal((b, n)), axis=1).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y)


def bench_merge_throughput(rows: List[Dict], smoke: bool = False) -> None:
    from repro.core import merge as core_merge
    from repro.kernels.merge_path import merge_pallas
    from repro.kernels.ref import merge_ref

    sizes = (1 << 14,) if smoke else (1 << 16, 1 << 20)
    for n in sizes:
        a, b = _sorted_pair(n)
        variants = {
            "flat_rank_merge": jax.jit(core_merge),
            "xla_sort_oracle": jax.jit(merge_ref),
            "pallas_spm_tile512": jax.jit(lambda x, y: merge_pallas(x, y, tile=512)),
        }
        for name, fn in variants.items():
            us = timeit(
                fn, a, b,
                iters=3 if smoke else 5, warmup=1 if smoke else 2,
                label=f"merge_throughput/{name}/n={2*n}",
            )
            rows.append({
                "name": f"merge_throughput/{name}/n={2*n}",
                "us_per_call": us,
                "derived": f"{2*n/us:.1f} Melem/s",
            })


def bench_batched_merge(rows: List[Dict], smoke: bool = False) -> None:
    """Batched Merge Path: one 2-D (batch, tile) grid launch for the whole
    batch vs the pairwise alternatives.

    Baselines:
    * ``pairwise_pallas_loop`` — the pre-batched-API strategy: one 1-D
      kernel launch per row pair (what a vmapped consumer effectively
      paid per row).
    * ``vmapped_core_merge`` — pure-JAX pairwise merge under ``vmap``.
    * ``fused_core_batched`` — the fused single-pass Algorithm 2 batched
      merge (no kernel), the small-row dispatch target of ``kernels.ops``.

    Sizes sit in the many-small-rows regime the batched API exists for
    (MoE dispatch rounds, top-k candidate runs): there the per-launch
    overhead of the pairwise loop dominates and the single 2-D-grid
    launch wins.  (In interpret mode, very long rows instead penalize the
    batched kernel — the interpreter carries the whole batch output
    through its grid loop — which on real hardware is pipelined away.)
    """
    from repro.core import merge as core_merge
    from repro.core.batched import merge_batched as core_merge_batched
    from repro.kernels.merge_path import merge_batched_pallas, merge_pallas

    bsz, n, tile = (32, 256, 64) if smoke else (64, 512, 128)
    a, b = _sorted_rows(bsz, n, seed=7)
    iters, warmup = (3, 1) if smoke else (5, 2)

    def pairwise_loop(x, y):
        return jnp.stack([merge_pallas(x[i], y[i], tile=tile) for i in range(bsz)])

    variants = {
        "batched_pallas_2d_grid": jax.jit(lambda x, y: merge_batched_pallas(x, y, tile=tile)),
        "pairwise_pallas_loop": jax.jit(pairwise_loop),
        "fused_core_batched": jax.jit(core_merge_batched),
        "vmapped_core_merge": jax.jit(jax.vmap(core_merge)),
    }
    us_by_name = {}
    for name, fn in variants.items():
        us = timeit(
            fn, a, b, iters=iters, warmup=warmup,
            label=f"batched_merge/{name}/B={bsz}/n={2*n}",
        )
        us_by_name[name] = us
        rows.append({
            "name": f"batched_merge/{name}/B={bsz}/n={2*n}",
            "us_per_call": us,
            "derived": f"{bsz*2*n/us:.1f} Melem/s",
        })
    ratio = us_by_name["pairwise_pallas_loop"] / us_by_name["batched_pallas_2d_grid"]
    rows.append({
        "name": f"batched_merge/speedup_batched_vs_pairwise/B={bsz}/n={2*n}",
        "us_per_call": 0.0,
        "derived": f"{ratio:.2f}x (2-D grid launch vs per-pair launches)",
    })


def bench_ragged_merge(rows: List[Dict], smoke: bool = False) -> None:
    """Ragged batched Merge Path (PR 2): per-row valid lengths.

    Two claims measured:
    * ``uniform_fused_batched`` — the regular (non-ragged) fused batched
      merge at the acceptance size (64, 4096).  This path is untouched by
      the ragged API (raggedness must not tax it); its timing is the
      regression anchor recorded in BENCH_*.json.
    * ``ragged_fused_batched`` — the same batch with random per-row valid
      lengths through ``merge_batched_ragged``: the price of length
      masking + capped ranks relative to the uniform pass.
    * ``ragged_relative_cost`` — the ratio (derived).
    """
    from repro.core.batched import merge_batched as core_merge_batched
    from repro.core.batched import merge_batched_ragged

    # the acceptance size (64, 4096) is kept in smoke mode too — it is the
    # regression anchor the acceptance criteria compare against
    bsz, n = 64, 4096
    a, b = _sorted_rows(bsz, n, seed=13)
    rng = np.random.default_rng(13)
    al = jnp.asarray(rng.integers(0, n + 1, bsz), jnp.int32)
    bl = jnp.asarray(rng.integers(0, n + 1, bsz), jnp.int32)
    iters, warmup = (3, 1) if smoke else (5, 2)
    us_uniform = timeit(
        jax.jit(core_merge_batched), a, b, iters=iters, warmup=warmup,
        label=f"ragged_merge/uniform_fused_batched/B={bsz}/n={2*n}",
    )
    us_ragged = timeit(
        jax.jit(merge_batched_ragged), a, b, al, bl, iters=iters, warmup=warmup,
        label=f"ragged_merge/ragged_fused_batched/B={bsz}/n={2*n}",
    )
    rows.append({
        "name": f"ragged_merge/uniform_fused_batched/B={bsz}/n={2*n}",
        "us_per_call": us_uniform,
        "derived": f"{bsz*2*n/us_uniform:.1f} Melem/s",
    })
    rows.append({
        "name": f"ragged_merge/ragged_fused_batched/B={bsz}/n={2*n}",
        "us_per_call": us_ragged,
        "derived": f"{bsz*2*n/us_ragged:.1f} Melem/s (storage elems)",
    })
    rows.append({
        "name": f"ragged_merge/ragged_relative_cost/B={bsz}/n={2*n}",
        "us_per_call": 0.0,
        "derived": f"{us_ragged/us_uniform:.2f}x uniform-path time",
    })


def bench_partition_cost(rows: List[Dict], smoke: bool = False) -> None:
    """Partition stage cost vs p on 10M elements — the paper's O(p log N)."""
    from repro.core import diagonal_intersections

    n = 250_000 if smoke else 5_000_000
    ps = (16, 256) if smoke else (16, 256, 4096)
    a, b = _sorted_pair(n)
    for p in ps:
        diags = jnp.arange(p, dtype=jnp.int32) * (2 * n // p)
        fn = jax.jit(diagonal_intersections)
        us = timeit(
            fn, a, b, diags,
            iters=3 if smoke else 5, warmup=1 if smoke else 2,
            label=f"partition_cost/p={p}/n={2*n}",
        )
        rows.append({
            "name": f"partition_cost/p={p}/n={2*n}",
            "us_per_call": us,
            "derived": f"{us/p:.3f} us/partition-point",
        })


def bench_load_balance(rows: List[Dict], smoke: bool = False) -> None:
    """Corollary 7: per-segment work is exactly N/p for every lane —
    measured from the diagonal partition, vs the naive equal-|A|-split."""
    from repro.core import diagonal_intersections

    n = 1 << 16 if smoke else 1 << 20
    rng = np.random.default_rng(3)
    # skewed inputs: all of A greater than most of B (the paper's
    # counterexample to naive partitioning, §1)
    a = jnp.asarray(np.sort(rng.standard_normal(n) + 3.0).astype(np.float32))
    b = jnp.asarray(np.sort(rng.standard_normal(n)).astype(np.float32))
    p = 64
    seg = 2 * n // p
    diags = jnp.arange(p + 1, dtype=jnp.int32) * seg
    ai = np.asarray(diagonal_intersections(a, b, diags))
    work_mp = np.diff(ai) + np.diff(np.asarray(diags) - ai)  # per-lane outputs
    # naive: give lane i an equal slice of A and of B; its work is whatever
    # the merge of those turns out to be (bounded only by 2N/p, cf. [9])
    na_per = n // p
    naive_hi = 2 * seg  # worst-case bound
    rows.append({
        "name": f"load_balance/merge_path/p={p}",
        "us_per_call": 0.0,
        "derived": f"max/min work {work_mp.max()}/{work_mp.min()} (ratio {work_mp.max()/max(1,work_mp.min()):.3f})",
    })
    rows.append({
        "name": f"load_balance/naive_bound/p={p}",
        "us_per_call": 0.0,
        "derived": f"worst-case lane work {naive_hi} = 2x mean (Shiloach-Vishkin bound)",
    })


def bench_segmented_vs_regular(rows: List[Dict], smoke: bool = False) -> None:
    from repro.core import merge as core_merge
    from repro.core import segmented_merge

    n = 1 << 17 if smoke else 1 << 21  # full: 8 MiB per array f32, beyond host L2
    segs = (1 << 12, 1 << 13) if smoke else (1 << 14, 1 << 16)
    a, b = _sorted_pair(n, seed=5)
    iters, warmup = (3, 1) if smoke else (5, 2)
    us_flat = timeit(
        jax.jit(core_merge), a, b, iters=iters, warmup=warmup,
        label=f"segmented_merge/flat_baseline/n={2*n}",
    )
    for seg in segs:
        fn = jax.jit(lambda x, y, s=seg: segmented_merge(x, y, s))
        us = timeit(
            fn, a, b, iters=iters, warmup=warmup,
            label=f"segmented_merge/seg={seg}/n={2*n}",
        )
        rows.append({
            "name": f"segmented_merge/seg={seg}/n={2*n}",
            "us_per_call": us,
            "derived": f"{us/us_flat:.2f}x flat-merge time",
        })
    rows.append({
        "name": f"segmented_merge/flat_baseline/n={2*n}",
        "us_per_call": us_flat,
        "derived": "1.00x",
    })


def bench_sort(rows: List[Dict], smoke: bool = False) -> None:
    from repro.core import merge_sort
    from repro.kernels import ops as kops

    sizes = (1 << 12,) if smoke else (1 << 14, 1 << 17)
    for n in sizes:
        rng = np.random.default_rng(n)
        x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        iters, warmup = (3, 1) if smoke else (5, 2)
        us_mp = timeit(
            jax.jit(merge_sort), x, iters=iters, warmup=warmup,
            label=f"sort/merge_path/n={n}",
        )
        us_xla = timeit(
            jax.jit(jnp.sort), x, iters=iters, warmup=warmup,
            label=f"sort/xla_baseline/n={n}",
        )
        # kernel-backed sort: wide rounds on the flat round kernel
        # (hierarchical engine, autotuned (tile, leaf), padding hoisted)
        us_ko = timeit(
            kops.sort, x, iters=iters, warmup=warmup,
            label=f"sort/pallas_flat_rounds/n={n}",
        )
        rows.append({
            "name": f"sort/merge_path/n={n}",
            "us_per_call": us_mp,
            "derived": f"{n/us_mp:.1f} Melem/s",
        })
        rows.append({
            "name": f"sort/pallas_flat_rounds/n={n}",
            "us_per_call": us_ko,
            "derived": f"{n/us_ko:.1f} Melem/s",
        })
        rows.append({
            "name": f"sort/xla_baseline/n={n}",
            "us_per_call": us_xla,
            "derived": f"{n/us_xla:.1f} Melem/s",
        })


def bench_moe_dispatch(rows: List[Dict], smoke: bool = False) -> None:
    import dataclasses

    from repro.configs import get_config
    from repro.models import init_params
    from repro.models.moe import moe_apply

    base = get_config("phi3.5-moe-42b-a6.6b").reduced()
    base = dataclasses.replace(base, num_experts=16, experts_per_token=2)
    bsz, seq = (2, 128) if smoke else (4, 512)
    x = jax.random.normal(jax.random.key(1), (bsz, seq, base.d_model))
    for mode in ("merge_path", "cumsum"):
        cfg = dataclasses.replace(base, moe_dispatch=mode)
        params = init_params(cfg, jax.random.key(0))
        layer0 = jax.tree.map(lambda t: t[0], params["layers"])
        fn = jax.jit(lambda p, xx, c=cfg: moe_apply(p, xx, c))
        us = timeit(
            fn, layer0["moe"], x,
            iters=3 if smoke else 5, warmup=1 if smoke else 2,
            label=f"moe_dispatch/{mode}/tokens={bsz*seq}",
        )
        rows.append({
            "name": f"moe_dispatch/{mode}/tokens={bsz*seq}",
            "us_per_call": us,
            "derived": f"{us/(bsz*seq):.3f} us/token",
        })
