"""Benchmarks mirroring the paper's tables/figures, adapted to this
environment (single-CPU host; TPU numbers come from the dry-run roofline).

Paper artifact -> benchmark:
* Fig 4/5 (speedup vs threads)      -> bench_load_balance (Corollary 7: the
  partition gives *exactly* equal per-lane work, the paper's precondition
  for linear speedup; we measure per-lane work spread directly) and
  bench_partition_cost (the O(p log N) partition stage, Table 1 col 1).
* Table 1 (cache misses)            -> bench_segmented_vs_regular (SPM vs
  flat merge wall time on CPU, where the host cache plays the role the
  paper's L2/L3 plays).
* merging throughput                -> bench_merge_throughput (Pallas SPM
  kernel vs XLA sort oracle vs flat rank-merge).
* merge-sort                        -> bench_sort.
* framework integration (DESIGN §3) -> bench_moe_dispatch (merge-path vs
  cumsum dispatch inside the MoE layer).
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np
import jax
import jax.numpy as jnp


def timeit(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time (us) of jitted fn(*args)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def _sorted_pair(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    a = np.sort(rng.standard_normal(n)).astype(np.float32)
    b = np.sort(rng.standard_normal(n)).astype(np.float32)
    return jnp.asarray(a), jnp.asarray(b)


def bench_merge_throughput(rows: List[Dict]) -> None:
    from repro.core import merge as core_merge
    from repro.kernels.merge_path import merge_pallas
    from repro.kernels.ref import merge_ref

    for n in (1 << 16, 1 << 20):
        a, b = _sorted_pair(n)
        variants = {
            "flat_rank_merge": jax.jit(core_merge),
            "xla_sort_oracle": jax.jit(merge_ref),
            "pallas_spm_tile512": jax.jit(lambda x, y: merge_pallas(x, y, tile=512)),
        }
        for name, fn in variants.items():
            us = timeit(fn, a, b)
            rows.append({
                "name": f"merge_throughput/{name}/n={2*n}",
                "us_per_call": us,
                "derived": f"{2*n/us:.1f} Melem/s",
            })


def bench_partition_cost(rows: List[Dict]) -> None:
    """Partition stage cost vs p on 10M elements — the paper's O(p log N)."""
    from repro.core import diagonal_intersections

    n = 5_000_000
    a, b = _sorted_pair(n)
    for p in (16, 256, 4096):
        diags = jnp.arange(p, dtype=jnp.int32) * (2 * n // p)
        fn = jax.jit(diagonal_intersections)
        us = timeit(fn, a, b, diags)
        rows.append({
            "name": f"partition_cost/p={p}/n={2*n}",
            "us_per_call": us,
            "derived": f"{us/p:.3f} us/partition-point",
        })


def bench_load_balance(rows: List[Dict]) -> None:
    """Corollary 7: per-segment work is exactly N/p for every lane —
    measured from the diagonal partition, vs the naive equal-|A|-split."""
    from repro.core import diagonal_intersections

    n = 1 << 20
    rng = np.random.default_rng(3)
    # skewed inputs: all of A greater than most of B (the paper's
    # counterexample to naive partitioning, §1)
    a = jnp.asarray(np.sort(rng.standard_normal(n) + 3.0).astype(np.float32))
    b = jnp.asarray(np.sort(rng.standard_normal(n)).astype(np.float32))
    p = 64
    seg = 2 * n // p
    diags = jnp.arange(p + 1, dtype=jnp.int32) * seg
    ai = np.asarray(diagonal_intersections(a, b, diags))
    work_mp = np.diff(ai) + np.diff(np.asarray(diags) - ai)  # per-lane outputs
    # naive: give lane i an equal slice of A and of B; its work is whatever
    # the merge of those turns out to be (bounded only by 2N/p, cf. [9])
    na_per = n // p
    naive_hi = 2 * seg  # worst-case bound
    rows.append({
        "name": f"load_balance/merge_path/p={p}",
        "us_per_call": 0.0,
        "derived": f"max/min work {work_mp.max()}/{work_mp.min()} (ratio {work_mp.max()/max(1,work_mp.min()):.3f})",
    })
    rows.append({
        "name": f"load_balance/naive_bound/p={p}",
        "us_per_call": 0.0,
        "derived": f"worst-case lane work {naive_hi} = 2x mean (Shiloach-Vishkin bound)",
    })


def bench_segmented_vs_regular(rows: List[Dict]) -> None:
    from repro.core import merge as core_merge
    from repro.core import segmented_merge

    n = 1 << 21  # 8 MiB per array f32: beyond this host's L2
    a, b = _sorted_pair(n, seed=5)
    us_flat = timeit(jax.jit(core_merge), a, b)
    for seg in (1 << 14, 1 << 16):
        fn = jax.jit(lambda x, y, s=seg: segmented_merge(x, y, s))
        us = timeit(fn, a, b)
        rows.append({
            "name": f"segmented_merge/seg={seg}/n={2*n}",
            "us_per_call": us,
            "derived": f"{us/us_flat:.2f}x flat-merge time",
        })
    rows.append({
        "name": f"segmented_merge/flat_baseline/n={2*n}",
        "us_per_call": us_flat,
        "derived": "1.00x",
    })


def bench_sort(rows: List[Dict]) -> None:
    from repro.core import merge_sort

    for n in (1 << 14, 1 << 17):
        rng = np.random.default_rng(n)
        x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        us_mp = timeit(jax.jit(merge_sort), x)
        us_xla = timeit(jax.jit(jnp.sort), x)
        rows.append({
            "name": f"sort/merge_path/n={n}",
            "us_per_call": us_mp,
            "derived": f"{n/us_mp:.1f} Melem/s",
        })
        rows.append({
            "name": f"sort/xla_baseline/n={n}",
            "us_per_call": us_xla,
            "derived": f"{n/us_xla:.1f} Melem/s",
        })


def bench_moe_dispatch(rows: List[Dict]) -> None:
    import dataclasses

    from repro.configs import get_config
    from repro.models import init_params
    from repro.models.moe import moe_apply

    base = get_config("phi3.5-moe-42b-a6.6b").reduced()
    base = dataclasses.replace(base, num_experts=16, experts_per_token=2)
    x = jax.random.normal(jax.random.key(1), (4, 512, base.d_model))
    for mode in ("merge_path", "cumsum"):
        cfg = dataclasses.replace(base, moe_dispatch=mode)
        params = init_params(cfg, jax.random.key(0))
        layer0 = jax.tree.map(lambda t: t[0], params["layers"])
        fn = jax.jit(lambda p, xx, c=cfg: moe_apply(p, xx, c))
        us = timeit(fn, layer0["moe"], x)
        rows.append({
            "name": f"moe_dispatch/{mode}/tokens={4*512}",
            "us_per_call": us,
            "derived": f"{us/(4*512):.3f} us/token",
        })
