"""HLO collective/op inspector for the perf hillclimb.

Compiles one (arch x shape) cell at shallow depth with cost-exact scans
and prints every collective op (kind, dtype, shape, bytes) plus the top
memory-traffic ops — the "profile" the §Perf loop iterates on.

    PYTHONPATH=src:. python -m benchmarks.hlo_inspect --arch tinyllama-1.1b --shape train_4k
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import re
from collections import defaultdict


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--depth", type=int, default=0, help="layers (0 = one group)")
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()

    from repro.configs import SHAPES_BY_NAME, TrainConfig, get_config
    from repro.launch import roofline as rl
    from repro.launch.dryrun import _compile_one, _depth_variant
    from repro.utils.costmode import set_cost_exact

    cfg = get_config(args.arch)
    depth = args.depth or cfg.layer_group
    cfg = _depth_variant(cfg, depth)
    shape = SHAPES_BY_NAME[args.shape]
    set_cost_exact(True)
    try:
        compiled, _, t = _compile_one(cfg, shape, args.multi_pod, TrainConfig())
    finally:
        set_cost_exact(False)
    hlo = compiled.as_text()
    print(f"# {args.arch} x {args.shape} depth={depth} compile={t:.1f}s "
          f"hlo={len(hlo)/1e6:.1f} MB")

    # collectives with shapes
    pat = re.compile(
        r"(\S+)\s*=\s*((?:\(?[\w\[\],{}\s/#*]*?\)?))\s*"
        r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)(-start)?\("
    )
    rows = []
    for m in pat.finditer(hlo):
        name, shp, kind = m.group(1), m.group(2), m.group(3)
        nbytes = rl._shape_bytes(shp)
        rows.append((nbytes, kind, shp.strip()[:90], name[:40]))
    rows.sort(reverse=True)
    agg = defaultdict(lambda: [0, 0.0])
    for nbytes, kind, shp, _ in rows:
        # aggregate by (kind, dtype)
        dt = re.match(r"\(?(\w+)\[", shp)
        key = (kind, dt.group(1) if dt else "?")
        agg[key][0] += 1
        agg[key][1] += nbytes
    print("\n## collectives by (kind, dtype)")
    for (kind, dt), (cnt, total) in sorted(agg.items(), key=lambda kv: -kv[1][1]):
        print(f"  {kind:20s} {dt:5s} x{cnt:<4d} {total/1e9:8.3f} GB")
    print(f"\n## top {args.top} collectives")
    for nbytes, kind, shp, name in rows[: args.top]:
        print(f"  {nbytes/1e6:10.1f} MB  {kind:18s} {shp}")

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    print(f"\nflops={cost.get('flops', 0):.3e}  bytes={cost.get('bytes accessed', 0):.3e}")
    # biggest single ops by output size: fusion/layout hot spots
    op_pat = re.compile(r"=\s*(\w+\[[\d,]*\])[^=]*?\b(fusion|dot|gather|scatter|convolution|"
                        r"dynamic-update-slice|transpose|copy|reduce)\b", re.M)
    ops = []
    for m in op_pat.finditer(hlo):
        ops.append((rl._shape_bytes(m.group(1)), m.group(2), m.group(1)))
    ops.sort(reverse=True)
    print(f"\n## top {args.top} op outputs by size")
    seen = set()
    shown = 0
    for nbytes, op, shp in ops:
        key = (op, shp)
        if key in seen:
            continue
        seen.add(key)
        print(f"  {nbytes/1e6:10.1f} MB  {op:22s} {shp}")
        shown += 1
        if shown >= args.top:
            break


if __name__ == "__main__":
    main()
